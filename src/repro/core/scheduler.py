"""Request scheduler for the MoSKA serving engine.

Slot-based continuous batching (static shapes for jit): a wave has B slots;
finished slots are refilled from the admission queue. Admission respects the
memory budget computed from the analytical model's capacity terms (unique KV
per request + resident shared stores), i.e. the scheduler enforces the
"batch scaling capability" of Fig. 4 at run time.

Chunk-level batching (queries grouped per shared chunk) happens *inside*
the attention (core/shared_attention.py); the scheduler's job is request
lifecycle + corpus affinity: requests over the same shared corpus are
steered into the same wave so the batched GEMM sees maximal N.

Under block-budget pressure the scheduler prefers **offloading** cold
resident pages over deferring work: the engine registers a cold-page
accountant + offloader (``set_page_offloader``), the budget then counts
pages held only by the device prefix cache, and an admission that would
otherwise defer first asks the engine to offload cold pages to the host
tier (or drop them when no host tier is configured). Only when stores,
cold pages, and blocks together still don't fit does the request defer
(``scheduler/admission_deferred_mem``); successful offload-funded
admissions count under ``scheduler/offload_admissions``.

A wave is **never mixed**: the decode step attends one shared store for
all slots, so every active request must be on the resident corpus
(``corpus_id=None`` counts as its own corpus — no store). Requests on a
different corpus are deferred until the resident wave drains, at which
point residency flips to the next admissible request's corpus.

Affinity is bounded: once a queue head has been skipped
``affinity_max_skips`` times in favor of resident-corpus traffic, the
scheduler stops admitting resident traffic, lets the wave drain, and then
flips residency to the head — so no corpus starves under a sustained
stream on another corpus.

Every admission/eviction decision is recorded in the process-global
metrics registry (``repro.obs``) under ``scheduler/*``: admission and
release counters, slot-occupancy and memory-headroom gauges, the
corpus-affinity hit/miss/preemption counters behind the batching-density
story, and a wave batch-density histogram.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Sequence

from repro import obs
from repro.kvcache.block_table import blocks_for


@dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int
    corpus_id: Optional[str] = None      # shared KV store this request uses
    arrival: float = 0.0
    # lifecycle
    generated: List[int] = field(default_factory=list)
    slot: int = -1
    done: bool = False
    skips: int = 0                       # affinity passes while queue head

    @property
    def remaining(self) -> int:
        return self.max_new_tokens - len(self.generated)


@dataclass
class SchedulerConfig:
    max_slots: int = 8
    mem_budget_bytes: float = float("inf")
    unique_bytes_per_token: int = 0      # cfg.kv_bytes_per_token
    max_seq: int = 2048
    corpus_affinity: bool = True
    # starvation bound: force the queue head after this many affinity skips
    affinity_max_skips: int = 64
    # "slotted": every admitted request is charged max_seq tokens of unique
    # KV. "paged": charged only the blocks its prompt + generation budget
    # actually needs (block-budget accounting; admits more concurrent
    # requests at equal HBM), and prompts may exceed max_seq.
    kv_layout: str = "slotted"
    block_size: int = 16


class Scheduler:
    def __init__(self, cfg: SchedulerConfig):
        self.cfg = cfg
        self.queue: Deque[Request] = collections.deque()
        self.slots: List[Optional[Request]] = [None] * cfg.max_slots
        self.finished: List[Request] = []
        self._uid = itertools.count()
        self.resident_corpus: Optional[str] = None
        # shared-store registry: corpus_id -> {nbytes, loaded, last_use}.
        # "loaded" stores hold device HBM and count against the budget;
        # cold ones are LRU-evicted via the engine's evictor callback and
        # reloaded on demand.
        self._stores: Dict[str, dict] = {}
        self._store_clock = itertools.count()
        self._store_evictor: Optional[Callable[[str], None]] = None
        # offload admission path (paged layout): bytes of cold resident
        # pages (held only by the engine's prefix cache) and a callback
        # that offloads/drops them, returning the bytes actually freed
        self._cold_bytes: Callable[[], float] = lambda: 0.0
        self._page_offloader: Optional[Callable[[float], float]] = None

    # ------------------------------------------------------------------
    def submit(self, prompt: Sequence[int], max_new_tokens: int,
               corpus_id: Optional[str] = None) -> int:
        if max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {max_new_tokens} "
                "(the prefill always produces one token)")
        if not prompt:
            raise ValueError("empty prompt")
        total = len(prompt) + max_new_tokens
        if self.cfg.kv_layout == "paged":
            cost = self._token_cost(total)
            if cost > self.cfg.mem_budget_bytes:
                raise ValueError(
                    f"prompt ({len(prompt)} tokens) + max_new_tokens "
                    f"({max_new_tokens}) needs "
                    f"{blocks_for(total, self.cfg.block_size)} KV blocks "
                    f"({cost:.3g} bytes), exceeding the block budget "
                    f"(mem_budget_bytes={self.cfg.mem_budget_bytes:.3g})")
        elif total > self.cfg.max_seq:
            raise ValueError(
                f"prompt ({len(prompt)} tokens) + max_new_tokens "
                f"({max_new_tokens}) exceeds max_seq={self.cfg.max_seq} "
                "for the slotted KV layout; the paged layout "
                "(EngineConfig(kv_layout='paged')) admits long prompts "
                "up to the block budget")
        uid = next(self._uid)
        self.queue.append(Request(uid, list(prompt), max_new_tokens,
                                  corpus_id))
        return uid

    # -- memory accounting ---------------------------------------------
    @property
    def shared_bytes(self) -> float:
        """Device bytes held by currently-loaded shared stores."""
        return sum(e["nbytes"] for e in self._stores.values() if e["loaded"])

    def _token_cost(self, n_tokens: int) -> float:
        bs = self.cfg.block_size
        return (blocks_for(n_tokens, bs) * bs *
                self.cfg.unique_bytes_per_token)

    def _slot_cost(self) -> float:
        return self.cfg.unique_bytes_per_token * self.cfg.max_seq

    def _request_cost(self, req: Optional[Request] = None) -> float:
        """Unique-KV bytes one request charges against the budget: a full
        max_seq slot in the slotted layout, only its own blocks in paged."""
        if self.cfg.kv_layout != "paged" or req is None:
            return self._slot_cost()
        return self._token_cost(len(req.prompt) + req.max_new_tokens)

    def _used_bytes(self) -> float:
        return self.shared_bytes + self._cold_bytes() + sum(
            self._request_cost(s) for s in self.slots if s is not None)

    def admissible(self, req: Optional[Request] = None) -> bool:
        return self._used_bytes() + self._request_cost(req) <= \
            self.cfg.mem_budget_bytes

    # -- shared-store registry / LRU eviction ---------------------------
    def set_store_evictor(self, fn: Callable[[str], None]) -> None:
        """Engine callback dropping a store's device arrays on eviction."""
        self._store_evictor = fn

    def set_page_offloader(self, cold_bytes: Callable[[], float],
                           offload: Callable[[float], float]) -> None:
        """Wire the host-tier offload admission path: ``cold_bytes()``
        reports device bytes held only by cold prefix pages (they now
        count against the budget), ``offload(need)`` offloads at least
        ``need`` of them (LRU order) and returns the bytes freed."""
        self._cold_bytes = cold_bytes
        self._page_offloader = offload

    def _offload_cold_for(self, req: Request) -> float:
        """Ask the engine to offload cold resident pages so ``req`` fits;
        returns the bytes freed (0.0 when no offloader is wired or no
        pressure exists)."""
        if self._page_offloader is None:
            return 0.0
        budget = self.cfg.mem_budget_bytes
        if budget == float("inf"):
            return 0.0
        shortfall = self._used_bytes() + self._request_cost(req) - budget
        if shortfall <= 0:
            return 0.0
        freed = self._page_offloader(shortfall)
        if freed > 0:
            reg = obs.get_registry()
            reg.inc("scheduler/page_offloads")
            reg.inc("scheduler/offload_freed_bytes", freed)
        return freed

    def register_store(self, corpus_id: str, nbytes: float) -> None:
        self._stores[corpus_id] = {"nbytes": float(nbytes), "loaded": True,
                                   "last_use": next(self._store_clock)}

    def touch_store(self, corpus_id: Optional[str]) -> None:
        e = self._stores.get(corpus_id)
        if e is not None:
            e["last_use"] = next(self._store_clock)

    def store_loaded(self, corpus_id: str) -> bool:
        e = self._stores.get(corpus_id)
        return bool(e and e["loaded"])

    def mark_store_loaded(self, corpus_id: str, loaded: bool = True) -> None:
        e = self._stores.get(corpus_id)
        if e is not None:
            e["loaded"] = loaded
            if loaded:
                e["last_use"] = next(self._store_clock)

    def _evict_stores_for(self, need_bytes: float,
                          keep: Optional[str] = None) -> bool:
        """LRU-evict cold loaded stores (never ``keep`` / the resident
        corpus) until ``need_bytes`` fits in the budget. Returns success."""
        reg = obs.get_registry()
        while self._used_bytes() + need_bytes > self.cfg.mem_budget_bytes:
            victims = [(e["last_use"], cid)
                       for cid, e in self._stores.items()
                       if e["loaded"] and cid != keep
                       and cid != self.resident_corpus]
            if not victims:
                return False
            _, cid = min(victims)
            self._stores[cid]["loaded"] = False
            reg.inc("scheduler/store_evictions")
            if self._store_evictor is not None:
                self._store_evictor(cid)
        return True

    # ------------------------------------------------------------------
    def schedule(self) -> List[Request]:
        """Fill free slots from the queue; returns newly admitted requests
        (they need a prefill before joining the decode wave)."""
        admitted: List[Request] = []
        for i, s in enumerate(self.slots):
            if s is not None or not self.queue:
                continue
            req = self._pick_next()
            if req is None:
                break
            offloaded = 0.0
            if not self.admissible(req):
                self._evict_stores_for(self._request_cost(req),
                                       keep=req.corpus_id)
            if not self.admissible(req):
                # offload-vs-defer: cold resident pages go to the host
                # tier (or are dropped) before any work is deferred
                offloaded = self._offload_cold_for(req)
            if not self.admissible(req):
                obs.get_registry().inc("scheduler/admission_deferred_mem")
                self.queue.appendleft(req)     # re-picked first next time
                break
            if offloaded > 0:
                obs.get_registry().inc("scheduler/offload_admissions")
            req.slot = i
            self.slots[i] = req
            admitted.append(req)
        self._record_wave(len(admitted))
        return admitted

    def _pick_next(self) -> Optional[Request]:
        """Pick the next request to admit, or None to defer.

        Invariant: the returned request's corpus always equals
        ``resident_corpus`` after the call — a wave never mixes corpora
        (the decode step attends exactly one shared store for all slots).
        """
        if not self.queue:
            return None
        reg = obs.get_registry()
        if not self.cfg.corpus_affinity:
            # affinity off still never mixes: admit only when the wave is
            # empty or the head matches the resident corpus
            head = self.queue[0]
            if self._wave_live() and head.corpus_id != self.resident_corpus:
                reg.inc("scheduler/affinity_deferrals")
                return None
            self.queue.popleft()
            self.resident_corpus = head.corpus_id
            return head
        head = self.queue[0]
        starved = head.skips >= self.cfg.affinity_max_skips
        if not self._wave_live():
            # empty wave: residency may flip freely
            if starved:
                if head.corpus_id != self.resident_corpus:
                    reg.inc("scheduler/affinity_preemptions")
                self.queue.popleft()
                self.resident_corpus = head.corpus_id
                return head
            for idx, r in enumerate(self.queue):
                if r.corpus_id == self.resident_corpus:
                    if idx:
                        head.skips += 1
                    del self.queue[idx]
                    reg.inc("scheduler/affinity_hits")
                    return r
            # resident corpus drained from the queue: flip to the head
            req = self.queue.popleft()
            self.resident_corpus = req.corpus_id
            reg.inc("scheduler/affinity_flips")
            return req
        # live wave on the resident corpus
        if starved and head.corpus_id != self.resident_corpus:
            # stop feeding the wave so it drains; the head preempts once
            # the last resident-corpus slot releases (bounded starvation)
            reg.inc("scheduler/affinity_drains")
            return None
        for idx, r in enumerate(self.queue):
            if r.corpus_id == self.resident_corpus:
                if idx:
                    head.skips += 1
                del self.queue[idx]
                reg.inc("scheduler/affinity_hits")
                return r
        # nothing on the resident corpus: defer rather than mix the wave
        head.skips += 1
        reg.inc("scheduler/affinity_misses")
        return None

    def lookahead(self, n: int) -> List[Request]:
        """Preview (never admit) up to ``n`` queued requests most likely
        to be admitted next — the prefetch engine's hint source.

        Mirrors ``_pick_next``'s affinity order without mutating any
        state (no skips counted, no residency flips, no queue edits):
        resident-corpus entries first in queue order, then the corpus
        residency would flip to once the wave drains (the first
        non-resident request's), again in queue order. A wrong
        prediction costs one wasted transfer, never correctness, so this
        stays deliberately simple (it ignores the starvation override; a
        starved head is the next flip target anyway)."""
        if n <= 0 or not self.queue:
            return []
        out: List[Request] = []
        for r in self.queue:
            if r.corpus_id == self.resident_corpus:
                out.append(r)
                if len(out) >= n:
                    return out
        # past the resident traffic, the next admissible corpus is the
        # one residency flips to when the wave drains
        flip = None
        for r in self.queue:
            if r.corpus_id == self.resident_corpus:
                continue
            if flip is None:
                flip = r.corpus_id
            if r.corpus_id == flip:
                out.append(r)
                if len(out) >= n:
                    break
        return out

    def _wave_live(self) -> bool:
        return any(s is not None for s in self.slots)

    def _record_wave(self, admitted: int) -> None:
        reg = obs.get_registry()
        if admitted:
            reg.inc("scheduler/admitted", admitted)
        n_active = sum(1 for s in self.slots if s is not None)
        occupancy = n_active / max(self.cfg.max_slots, 1)
        reg.set_gauge("scheduler/slot_occupancy", occupancy)
        reg.set_gauge("scheduler/queue_depth", len(self.queue))
        reg.observe("scheduler/wave_batch_density", occupancy,
                    obs.FRACTION_EDGES)
        budget = self.cfg.mem_budget_bytes
        # -1 marks an unbounded budget (inf is not JSON-portable)
        reg.set_gauge("scheduler/mem_headroom_bytes",
                      budget - self._used_bytes()
                      if budget != float("inf") else -1.0)

    # ------------------------------------------------------------------
    def active(self) -> List[Request]:
        return [s for s in self.slots if s is not None]

    def record_token(self, req: Request, token: int, eos_id: int = -1):
        req.generated.append(token)
        if req.remaining <= 0 or token == eos_id:
            req.done = True
            self.finished.append(req)
            self.slots[req.slot] = None
            req.slot = -1
            reg = obs.get_registry()
            reg.inc("scheduler/slots_released")
            reg.inc("scheduler/completed")

    @property
    def idle(self) -> bool:
        return not self.queue and all(s is None for s in self.slots)


def wave_stats(reqs: List[Request]) -> Dict[str, float]:
    """Chunk-batching diagnostics: how much GEMM batching a wave provides."""
    by_corpus = collections.Counter(r.corpus_id for r in reqs)
    return {
        "wave_size": len(reqs),
        "distinct_corpora": len(by_corpus),
        "max_corpus_batch": max(by_corpus.values()) if by_corpus else 0,
    }
