"""Request scheduler for the MoSKA serving engine.

Slot-based continuous batching (static shapes for jit): a wave has B slots;
finished slots are refilled from the admission queue. Admission respects the
memory budget computed from the analytical model's capacity terms (unique KV
per request + resident shared stores), i.e. the scheduler enforces the
"batch scaling capability" of Fig. 4 at run time.

Chunk-level batching (queries grouped per shared chunk) happens *inside*
the attention (core/shared_attention.py); the scheduler's job is request
lifecycle + corpus affinity: requests over the same shared corpus are
steered into the same wave so the batched GEMM sees maximal N.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Sequence


@dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int
    corpus_id: Optional[str] = None      # shared KV store this request uses
    arrival: float = 0.0
    # lifecycle
    generated: List[int] = field(default_factory=list)
    slot: int = -1
    done: bool = False

    @property
    def remaining(self) -> int:
        return self.max_new_tokens - len(self.generated)


@dataclass
class SchedulerConfig:
    max_slots: int = 8
    mem_budget_bytes: float = float("inf")
    unique_bytes_per_token: int = 0      # cfg.kv_bytes_per_token
    max_seq: int = 2048
    corpus_affinity: bool = True


class Scheduler:
    def __init__(self, cfg: SchedulerConfig):
        self.cfg = cfg
        self.queue: Deque[Request] = collections.deque()
        self.slots: List[Optional[Request]] = [None] * cfg.max_slots
        self.finished: List[Request] = []
        self._uid = itertools.count()
        self.resident_corpus: Optional[str] = None
        self.shared_bytes: float = 0.0

    # ------------------------------------------------------------------
    def submit(self, prompt: Sequence[int], max_new_tokens: int,
               corpus_id: Optional[str] = None) -> int:
        uid = next(self._uid)
        self.queue.append(Request(uid, list(prompt), max_new_tokens,
                                  corpus_id))
        return uid

    def _slot_cost(self) -> float:
        return self.cfg.unique_bytes_per_token * self.cfg.max_seq

    def _used_bytes(self) -> float:
        n = sum(1 for s in self.slots if s is not None)
        return self.shared_bytes + n * self._slot_cost()

    def admissible(self) -> bool:
        return self._used_bytes() + self._slot_cost() <= \
            self.cfg.mem_budget_bytes

    # ------------------------------------------------------------------
    def schedule(self) -> List[Request]:
        """Fill free slots from the queue; returns newly admitted requests
        (they need a prefill before joining the decode wave)."""
        admitted: List[Request] = []
        for i, s in enumerate(self.slots):
            if s is not None or not self.queue:
                continue
            if not self.admissible():
                break
            req = self._pick_next()
            if req is None:
                break
            req.slot = i
            self.slots[i] = req
            admitted.append(req)
        return admitted

    def _pick_next(self) -> Optional[Request]:
        if not self.queue:
            return None
        if not self.cfg.corpus_affinity or self.resident_corpus is None:
            req = self.queue.popleft()
            self.resident_corpus = req.corpus_id
            return req
        # prefer requests on the resident corpus: keeps the batched GEMM hot
        for idx, r in enumerate(self.queue):
            if r.corpus_id == self.resident_corpus:
                del self.queue[idx]
                return r
        return self.queue.popleft()

    # ------------------------------------------------------------------
    def active(self) -> List[Request]:
        return [s for s in self.slots if s is not None]

    def record_token(self, req: Request, token: int, eos_id: int = -1):
        req.generated.append(token)
        if req.remaining <= 0 or token == eos_id:
            req.done = True
            self.finished.append(req)
            self.slots[req.slot] = None
            req.slot = -1

    @property
    def idle(self) -> bool:
        return not self.queue and all(s is None for s in self.slots)


def wave_stats(reqs: List[Request]) -> Dict[str, float]:
    """Chunk-batching diagnostics: how much GEMM batching a wave provides."""
    by_corpus = collections.Counter(r.corpus_id for r in reqs)
    return {
        "wave_size": len(reqs),
        "distinct_corpora": len(by_corpus),
        "max_corpus_batch": max(by_corpus.values()) if by_corpus else 0,
    }
