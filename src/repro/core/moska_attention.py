"""MoSKA mixture attention: unique-KV partial ⊕ routed shared-KV partial.

This is the per-layer attention used at decode/prefill when a shared corpus
is attached. The unique path is the memory-bound GEMV over the request's own
cache (Fig. 2a left); the shared path is the routed, batched GEMM
(`shared_attention_batched`); the two partials are exact-merged via LSE —
the softmax over the union of the two key sets is recovered exactly
(property-tested in tests/test_moska_core.py).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import obs
from repro.configs.base import MoSKAConfig
from repro.core import router as router_lib
from repro.core import shared_attention as sa
from repro.models import layers as L


def _record_merge(lse_u: jax.Array, lse_s: jax.Array, phase: str) -> None:
    """Mixture diagnostics: how much attention mass the routed shared path
    contributes vs the request's unique cache (per-head win fraction).
    jit-safe; no-op unless the engine enabled jit metrics."""
    if not obs.metrics.JIT_METRICS:
        return
    obs.jit_inc(f"moska/{phase}/calls", 1)
    obs.jit_observe(f"moska/{phase}/shared_win_frac",
                    jnp.mean((lse_s > lse_u).astype(jnp.float32)),
                    edges=obs.FRACTION_EDGES)


class MoskaLayerContext(NamedTuple):
    """Per-layer shared store slices + routing, computed once per step."""
    k: jax.Array                         # (E, C, KH, D)
    v: jax.Array                         # (E, C, KH, D)
    routing: router_lib.Routing


def route_layer(q_pooled: jax.Array, emb: jax.Array,
                cfg: MoSKAConfig) -> router_lib.Routing:
    return router_lib.route(q_pooled, emb, cfg.top_k_chunks)


def moska_decode_attention(
    q: jax.Array,                        # (B, H, D) one token per request
    k_cache: jax.Array,                  # (B, S, KH, D) unique cache
    v_cache: jax.Array,
    kv_len: jax.Array,                   # (B,)
    ctx: Optional[MoskaLayerContext],
    cfg: MoSKAConfig,
    *,
    window: int = 0,
    kernel: Optional[str] = None,
    layer_idx: Optional[jax.Array] = None,
) -> jax.Array:
    """Returns merged attention output (B, H, D)."""
    o_u, lse_u = L.decode_attention(q, k_cache, v_cache, kv_len,
                                    window=window, return_lse=True)
    if ctx is None or not cfg.enabled:
        return o_u
    part = sa.shared_attention_batched(
        q[:, None], ctx.k, ctx.v, ctx.routing,
        capacity_factor=cfg.query_capacity_factor, kernel=kernel,
        layer_idx=layer_idx)
    o_s = part.out[:, 0]                 # (B, H, D)
    lse_s = part.lse[:, 0]               # (B, H)
    _record_merge(lse_u, lse_s, "decode")
    out, _ = L.merge_partial_attention([o_u, o_s], [lse_u, lse_s])
    return out


def moska_prefill_attention(
    q: jax.Array,                        # (B, S, H, D)
    k: jax.Array,                        # (B, S, KH, D) fresh unique keys
    v: jax.Array,
    ctx: Optional[MoskaLayerContext],
    cfg: MoSKAConfig,
    *,
    q_offset: int = 0,
    window: int = 0,
    route_block: int = 128,
    kernel: Optional[str] = None,
    layer_idx: Optional[jax.Array] = None,
) -> jax.Array:
    """Prefill: causal attention over the unique prefix, plus routed shared
    attention for every query block when a shared corpus is attached."""
    o_u, lse_u = L.flash_attention(q, k, v, causal=True, q_offset=q_offset,
                                   kv_offset=q_offset, window=window,
                                   return_lse=True)
    if ctx is None or not cfg.enabled:
        return o_u
    B, S, H, D = q.shape
    nb = S // route_block
    # (B*nb) groups of route_block queries
    qg = q.reshape(B * nb, route_block, H, D)
    part = sa.shared_attention_batched(
        qg, ctx.k, ctx.v, ctx.routing,
        capacity_factor=cfg.query_capacity_factor, kernel=kernel,
        layer_idx=layer_idx)
    o_s = part.out.reshape(B, S, H, D)
    lse_s = part.lse.reshape(B, S, H)
    _record_merge(lse_u, lse_s, "prefill")
    out, _ = L.merge_partial_attention([o_u, o_s], [lse_u, lse_s])
    return out
