"""Shared KV chunk store — the persistent, massively-reused corpus KV.

The paper (§III.A/B) manages the shared context as pre-computed,
position-annotated KV chunks ("experts"). The store is a pytree so it
shards: the chunk axis is the paper's *Shared KV node pool* and is sharded
over the ``data`` (and ``pod``) mesh axes at serve time (DESIGN.md §5).

Layout (stacked over layers so the decoder `lax.scan` consumes one slice
per layer):
    k, v : (L, n_chunks, chunk_size, kv_heads, head_dim)   post-RoPE keys
    emb  : (L, n_chunks, kv_heads, head_dim)               router embeddings
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


class SharedKVStore(NamedTuple):
    k: jax.Array            # (L, E, C, KH, D)  bf16, or int8 when quantized
    v: jax.Array            # (L, E, C, KH, D)
    emb: jax.Array          # (L, E, KH, D) mean-key chunk embeddings
    # absolute corpus position of the first token of each chunk; chunk i is
    # contiguous. positional=False => chunk-local positions (Universal MoSKA)
    chunk_positions: jax.Array  # (E,) int32
    # int8 quantization scales (None => unquantized). Per (layer, chunk,
    # token, kv_head): the TPU analogue of the paper's FP8 KV (v5e has no
    # FP8; int8 gives the same capacity/bandwidth halving).
    k_scale: Optional[jax.Array] = None   # (L, E, C, KH) f32
    v_scale: Optional[jax.Array] = None

    @property
    def quantized(self) -> bool:
        return self.k_scale is not None

    def dequantize_layer(self, i):
        """Return (k, v) of layer i in compute dtype."""
        if not self.quantized:
            return self.k[i], self.v[i]
        k = self.k[i].astype(jnp.bfloat16) * \
            self.k_scale[i][..., None].astype(jnp.bfloat16)
        v = self.v[i].astype(jnp.bfloat16) * \
            self.v_scale[i][..., None].astype(jnp.bfloat16)
        return k, v

    @property
    def num_layers(self) -> int:
        return self.k.shape[0]

    @property
    def num_chunks(self) -> int:
        return self.k.shape[1]

    @property
    def chunk_size(self) -> int:
        return self.k.shape[2]

    @property
    def total_tokens(self) -> int:
        return self.num_chunks * self.chunk_size

    def layer(self, i) -> "SharedKVStore":
        return SharedKVStore(self.k[i], self.v[i], self.emb[i],
                             self.chunk_positions)


def chunk_embeddings(k_chunks: jax.Array) -> jax.Array:
    """Training-free router embeddings: mean key per chunk (LongHeads/MoBA).

    k_chunks: (..., E, C, KH, D) -> (..., E, KH, D)
    """
    return jnp.mean(k_chunks.astype(jnp.float32), axis=-3).astype(
        k_chunks.dtype)


def _quantize(x: jax.Array):
    """(..., D) -> int8 values + per-row f32 scale."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def build_store(k: jax.Array, v: jax.Array, chunk_size: int,
                start_position: int = 0,
                quantize: bool = False) -> SharedKVStore:
    """Chunk a (L, S, KH, D) corpus KV into a SharedKVStore.

    Keys are expected post-RoPE at absolute corpus positions
    ``start_position + [0, S)``; S must be a multiple of chunk_size.
    ``quantize=True`` stores int8 KV + per-(token, head) f32 scales
    (capacity/bandwidth parity with the paper's FP8 assumption).
    """
    L, S, KH, D = k.shape
    if S % chunk_size:
        raise ValueError(f"corpus length {S} not a multiple of chunk_size "
                         f"{chunk_size}")
    E = S // chunk_size
    kc = k.reshape(L, E, chunk_size, KH, D)
    vc = v.reshape(L, E, chunk_size, KH, D)
    emb = chunk_embeddings(kc)
    pos = start_position + jnp.arange(E, dtype=jnp.int32) * chunk_size
    if not quantize:
        return SharedKVStore(kc, vc, emb, pos)
    kq, ks = _quantize(kc)
    vq, vs = _quantize(vc)
    return SharedKVStore(kq, vq, emb, pos, ks, vs)


def abstract_store(cfg: ModelConfig, shared_tokens: int,
                   dtype=jnp.bfloat16) -> SharedKVStore:
    """ShapeDtypeStruct stand-in for dry-runs (no allocation)."""
    C = cfg.moska.chunk_size
    E = shared_tokens // C
    L = cfg.num_attention_layers
    KH, D = cfg.num_kv_heads, cfg.head_dim
    sds = jax.ShapeDtypeStruct
    quant = cfg.moska.kv_quant == "int8"
    return SharedKVStore(
        k=sds((L, E, C, KH, D), jnp.int8 if quant else dtype),
        v=sds((L, E, C, KH, D), jnp.int8 if quant else dtype),
        emb=sds((L, E, KH, D), dtype),
        chunk_positions=sds((E,), jnp.int32),
        k_scale=sds((L, E, C, KH), jnp.float32) if quant else None,
        v_scale=sds((L, E, C, KH), jnp.float32) if quant else None,
    )
