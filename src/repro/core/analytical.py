"""The paper's analytical performance model (§IV, Figs. 1, 4, 5).

A roofline/capacity model in the style the paper cites (LIFE): throughput
predicted from compute FLOPS, memory capacity, and memory bandwidth. The
paper does not publish its constants; every assumption here is explicit and
swept in benchmarks/bench_fig4.py. EXPERIMENTS.md §Fidelity records which
workload point recovers the headline 538.7x.

Key mechanics reproduced:
  * capacity: methods without KV reuse store (shared+unique) KV per request;
    reuse stores shared once (Fig. 1b left).
  * bandwidth: non-batched methods read the shared KV once *per request*
    per step (GEMV); batched methods (ChunkAttention prefixes, MoSKA any
    chunk) read each active chunk once *per step* (GEMM) — Fig. 1b right.
  * sparsity: routed methods (LongHeads, MoBA, MoSKA) read/compute only the
    routed fraction per request.
  * reuse also skips the shared-context *prefill*: non-reuse baselines pay
    a full 16M-token prefill per request — the dominant cost in
    high-sharing workloads and the main source of the paper's headline gap.
  * disaggregation (MoSKA): unique and shared work run on separate node
    pools and are limited independently.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional


# ---------------------------------------------------------------------------
# hardware / model / workload descriptions
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class GPUSpec:
    name: str = "H200"
    mem_bytes: float = 141 * 2**30
    bw: float = 4.8e12
    flops_fp8: float = 1979e12
    flops_fp16: float = 989.5e12

    def flops(self, dtype: str) -> float:
        return self.flops_fp8 if dtype == "fp8" else self.flops_fp16


@dataclass(frozen=True)
class ClusterSpec:
    gpu: GPUSpec = field(default_factory=GPUSpec)
    gpus_per_node: int = 8
    num_nodes: int = 2

    @property
    def total_mem(self) -> float:
        return self.gpu.mem_bytes * self.gpus_per_node * self.num_nodes

    @property
    def total_bw(self) -> float:
        return self.gpu.bw * self.gpus_per_node * self.num_nodes

    def total_flops(self, dtype: str) -> float:
        return self.gpu.flops(dtype) * self.gpus_per_node * self.num_nodes

    def node_mem(self) -> float:
        return self.gpu.mem_bytes * self.gpus_per_node

    def node_bw(self) -> float:
        return self.gpu.bw * self.gpus_per_node

    def node_flops(self, dtype: str) -> float:
        return self.gpu.flops(dtype) * self.gpus_per_node


@dataclass(frozen=True)
class LLMSpec:
    """Llama 3.1 8B by default (the paper's model)."""
    name: str = "llama3.1-8b"
    num_layers: int = 32
    d_model: int = 4096
    num_heads: int = 32
    num_kv_heads: int = 8
    head_dim: int = 128
    d_ff: int = 14336
    vocab: int = 128256
    params: float = 8.03e9

    def kv_bytes_per_token(self, dtype: str) -> float:
        itemsize = 1 if dtype == "fp8" else 2
        return 2 * self.num_layers * self.num_kv_heads * self.head_dim * itemsize

    def attn_flops_per_token(self, context: float) -> float:
        # scores (2*H*hd*ctx) + PV (2*H*hd*ctx), summed over layers
        return 4 * self.num_heads * self.head_dim * self.num_layers * context

    def linear_flops_per_token(self) -> float:
        return 2.0 * self.params


@dataclass(frozen=True)
class Workload:
    shared_tokens: float = 16 * 2**20
    unique_tokens: float = 64 * 2**10
    slo_tokens_per_s: float = 35.0
    output_tokens: float = 128.0     # generated tokens per request
    chunk_tokens: float = 2048.0
    dtype: str = "fp8"
    # fraction of the shared context that is a strict common PREFIX.
    # Prefix-matching systems (SGLang, ChunkAttention, FlashInfer) can only
    # reuse/batch this part (§II.B); MoSKA batches any identical chunk.
    prefix_fraction: float = 1.0
    # how much concurrent requests' routed chunk sets overlap (CAG domain
    # locality). 1.0: all requests hit the same keep_frac hot set; 0.0: iid.
    route_locality: float = 0.9
    # SLO slack tolerated before a batch point is declared infeasible
    slo_tolerance: float = 0.05


@dataclass(frozen=True)
class Method:
    """Feature flags per Table I."""
    name: str
    kv_reuse: bool            # shared KV stored once & prefill skipped
    shared_batched: bool      # GEMM batching of shared reads (read once/step)
    sparse: bool              # routed sparse attention (read keep_frac)
    disagg: bool              # dedicated unique/shared node pools
    keep_frac: float = 0.25   # paper: 75% sparsity


FLASH_ATTENTION = Method("FlashAttention", False, False, False, False)
SGLANG = Method("SGLang", True, False, False, False)
LONGHEADS = Method("LongHeads", False, False, True, False)
CHUNK_ATTENTION = Method("ChunkAttention", True, True, False, False)
MOSKA = Method("MoSKA", True, True, True, True)

METHODS = [FLASH_ATTENTION, SGLANG, LONGHEADS, CHUNK_ATTENTION, MOSKA]


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------

@dataclass
class Point:
    method: str
    shared_tokens: float
    max_batch: int
    decode_rate: float          # achievable tokens/s/request at max_batch
    throughput: float           # aggregate effective tokens/s
    capacity_used: float        # bytes
    bw_bound: float             # steps/s bound from bandwidth
    compute_bound: float        # steps/s bound from compute
    throughput_amortized: float = 0.0  # incl. per-request prefill recompute
    unique_node_mfu: float = 0.0
    shared_node_mfu: float = 0.0
    unique_node_mem: float = 0.0
    shared_node_mem: float = 0.0
    unique_node_bw: float = 0.0
    shared_node_bw: float = 0.0


def _sharable_tokens(m: Method, w: Workload) -> float:
    """Tokens of shared context this method can actually reuse/batch.

    MoSKA's chunk registry is position-independent within the corpus; prefix
    systems reuse only the strict common prefix (§II.B). The remainder
    behaves like additional *unique* context for them.
    """
    if m.name == "MoSKA":
        return w.shared_tokens
    return w.shared_tokens * w.prefix_fraction


def _effective_unique(m: Method, w: Workload) -> float:
    return w.unique_tokens + (w.shared_tokens - _sharable_tokens(m, w)
                              if m.kv_reuse else 0.0)


def _capacity_bytes(m: Method, b: int, llm: LLMSpec, w: Workload,
                    cluster: ClusterSpec) -> float:
    kvb = llm.kv_bytes_per_token(w.dtype)
    weights = llm.params * (1 if w.dtype == "fp8" else 2) * cluster.num_nodes
    if m.kv_reuse:
        unique = b * _effective_unique(m, w) * kvb
        shared = _sharable_tokens(m, w) * kvb   # stored once
    else:
        unique = b * w.unique_tokens * kvb
        shared = b * w.shared_tokens * kvb      # per request
    return weights + unique + shared


def _union_fraction(frac: float, locality: float, b: int) -> float:
    """Fraction of chunks touched by >=1 of b requests routing to ``frac``."""
    if frac >= 1.0:
        return 1.0
    iid = 1.0 - (1.0 - frac) ** b
    return frac + (1.0 - locality) * (iid - frac)


def _decode_step_bytes(m: Method, b: int, llm: LLMSpec, w: Workload):
    """(unique_bytes, shared_bytes) read from memory per decode step."""
    kvb = llm.kv_bytes_per_token(w.dtype)
    frac = m.keep_frac if m.sparse else 1.0
    sharable = _sharable_tokens(m, w)
    if m.kv_reuse:
        unique = b * _effective_unique(m, w) * kvb
    else:
        # non-reuse methods still read their private copy of everything
        unique = b * (w.unique_tokens + frac * w.shared_tokens) * kvb
        sharable = 0.0
    if m.shared_batched and sharable > 0:
        union = _union_fraction(frac, w.route_locality, b)
        shared = union * sharable * kvb         # each active chunk read once
    else:
        shared = b * frac * sharable * kvb      # per-request GEMV re-reads
    # weights are also streamed once per step (FFN/projections)
    weights = llm.params * (1 if w.dtype == "fp8" else 2)
    return unique + weights, shared


def _decode_step_flops(m: Method, b: int, llm: LLMSpec, w: Workload):
    frac = m.keep_frac if m.sparse else 1.0
    unique = b * (llm.attn_flops_per_token(w.unique_tokens)
                  + llm.linear_flops_per_token())
    shared = b * llm.attn_flops_per_token(frac * w.shared_tokens)
    return unique, shared


def _decode_rate(m: Method, b: int, llm: LLMSpec, w: Workload,
                 cluster: ClusterSpec):
    """steps/s achievable at batch b, plus the individual bounds."""
    ub, sb = _decode_step_bytes(m, b, llm, w)
    uf, sf = _decode_step_flops(m, b, llm, w)
    if m.disagg:
        # unique pool: num_nodes-1 nodes... the paper dedicates 1 node each
        u_nodes = max(cluster.num_nodes - 1, 1)
        s_nodes = 1
        bw_bound = min(u_nodes * cluster.node_bw() / max(ub, 1e-9),
                       s_nodes * cluster.node_bw() / max(sb, 1e-9))
        fl_bound = min(u_nodes * cluster.node_flops(w.dtype) / max(uf, 1e-9),
                       s_nodes * cluster.node_flops(w.dtype) / max(sf, 1e-9))
    else:
        bw_bound = cluster.total_bw / max(ub + sb, 1e-9)
        fl_bound = cluster.total_flops(w.dtype) / max(uf + sf, 1e-9)
    return min(bw_bound, fl_bound), bw_bound, fl_bound


def _prefill_seconds(m: Method, llm: LLMSpec, w: Workload,
                     cluster: ClusterSpec) -> float:
    """Per-request prefill cost. Reuse methods only prefill the contexts
    they cannot cache; others recompute the shared context too."""
    if m.kv_reuse:
        tokens = _effective_unique(m, w)
    else:
        tokens = w.unique_tokens + w.shared_tokens
    flops = tokens * (llm.linear_flops_per_token()
                      + llm.attn_flops_per_token(tokens / 2.0))
    eff = 0.5  # sustained prefill efficiency
    return flops / (cluster.total_flops(w.dtype) * eff)


def _capacity_batch(m: Method, llm: LLMSpec, w: Workload,
                    cluster: ClusterSpec) -> int:
    kvb = llm.kv_bytes_per_token(w.dtype)
    if m.disagg:
        # unique KV on the unique pool; shared store on the shared pool
        u_nodes = max(cluster.num_nodes - 1, 1)
        u_mem = u_nodes * cluster.node_mem() - llm.params
        spill = max(_sharable_tokens(m, w) * kvb - cluster.node_mem(), 0.0)
        per_req = _effective_unique(m, w) * kvb
        return max(int((u_mem - spill) // per_req), 0)
    lo, hi = 0, 1
    while (_capacity_bytes(m, hi, llm, w, cluster) <= cluster.total_mem
           and hi < 10**7):
        lo, hi = hi, hi * 2
    while lo + 1 < hi:
        mid = (lo + hi) // 2
        if _capacity_bytes(m, mid, llm, w, cluster) <= cluster.total_mem:
            lo = mid
        else:
            hi = mid
    return lo


def _max_batch(m: Method, llm: LLMSpec, w: Workload,
               cluster: ClusterSpec) -> int:
    """Largest batch with (a) KV fitting in memory, (b) decode meeting SLO
    (within tolerance). rate(b) is monotone non-increasing: binary search."""
    cap_b = _capacity_batch(m, llm, w, cluster)
    if cap_b == 0:
        return 0
    slo = w.slo_tokens_per_s * (1.0 - w.slo_tolerance)

    def ok(b):
        return _decode_rate(m, b, llm, w, cluster)[0] >= slo

    if ok(cap_b):
        return cap_b
    if not ok(1):
        return 0
    lo, hi = 1, cap_b
    while lo + 1 < hi:
        mid = (lo + hi) // 2
        if ok(mid):
            lo = mid
        else:
            hi = mid
    return lo


def evaluate(m: Method, llm: LLMSpec, w: Workload,
             cluster: ClusterSpec) -> Point:
    b = _max_batch(m, llm, w, cluster)
    if b == 0:
        return Point(m.name, w.shared_tokens, 0, 0.0, 0.0,
                     _capacity_bytes(m, 1, llm, w, cluster), 0.0, 0.0)
    rate, bw_bound, fl_bound = _decode_rate(m, b, llm, w, cluster)
    rate = min(rate, w.slo_tokens_per_s)
    # primary (Fig. 4) metric: steady-state decode throughput
    thr = b * rate
    # secondary: amortized over per-request prefill recomputation
    t_pre = _prefill_seconds(m, llm, w, cluster)
    t_dec = w.output_tokens / rate
    thr_am = b * w.output_tokens / (t_pre + t_dec)

    p = Point(m.name, w.shared_tokens, b, rate, thr,
              _capacity_bytes(m, b, llm, w, cluster), bw_bound, fl_bound,
              throughput_amortized=thr_am)

    # node-level utilization (Fig. 5)
    kvb = llm.kv_bytes_per_token(w.dtype)
    ub, sb = _decode_step_bytes(m, b, llm, w)
    uf, sf = _decode_step_flops(m, b, llm, w)
    node_mem = cluster.node_mem()
    u_nodes = max(cluster.num_nodes - 1, 1) if m.disagg else cluster.num_nodes
    p.unique_node_mem = (b * _effective_unique(m, w) * kvb + llm.params) / (
        u_nodes * node_mem)
    p.shared_node_mem = min(_sharable_tokens(m, w) * kvb / node_mem, 1.0)
    p.unique_node_bw = rate * ub / (u_nodes * cluster.node_bw())
    p.shared_node_bw = rate * sb / cluster.node_bw()
    p.unique_node_mfu = rate * uf / (u_nodes * cluster.node_flops(w.dtype))
    # shared-node MFU: kernel-level roofline utilization of the batched GEMM
    # (operational intensity vs ridge point; see DESIGN.md)
    kv_read = sb if sb > 0 else 1.0
    intensity = sf / kv_read
    ridge = cluster.gpu.flops(w.dtype) / cluster.gpu.bw
    p.shared_node_mfu = min(1.0, intensity / ridge) * 0.85
    return p


def sweep_shared_context(methods: List[Method] = METHODS,
                         shared_grid: Optional[List[float]] = None,
                         llm: LLMSpec = LLMSpec(),
                         w: Workload = Workload(),
                         cluster: ClusterSpec = ClusterSpec()
                         ) -> Dict[str, List[Point]]:
    """Fig. 4: batch capability + throughput vs shared context size."""
    if shared_grid is None:
        shared_grid = [m * 2**20 for m in (1, 2, 4, 8, 16)]
    out: Dict[str, List[Point]] = {}
    for m in methods:
        pts = []
        for s in shared_grid:
            pts.append(evaluate(m, llm, dataclasses.replace(
                w, shared_tokens=s), cluster))
        out[m.name] = pts
    return out


def utilization_vs_batch(m: Method, batches: List[int],
                         llm: LLMSpec = LLMSpec(), w: Workload = Workload(),
                         cluster: ClusterSpec = ClusterSpec()) -> List[Point]:
    """Fig. 5: force batch sizes, report node utilization."""
    pts = []
    for b in batches:
        rate, bw_bound, fl_bound = _decode_rate(m, b, llm, w, cluster)
        rate = min(rate, w.slo_tokens_per_s)
        p = Point(m.name, w.shared_tokens, b, rate, b * rate,
                  _capacity_bytes(m, b, llm, w, cluster), bw_bound, fl_bound)
        kvb = llm.kv_bytes_per_token(w.dtype)
        ub, sb = _decode_step_bytes(m, b, llm, w)
        uf, sf = _decode_step_flops(m, b, llm, w)
        u_nodes = max(cluster.num_nodes - 1, 1)
        p.unique_node_mem = min((b * w.unique_tokens * kvb + llm.params)
                                / (u_nodes * cluster.node_mem()), 1.0)
        p.shared_node_mem = min(w.shared_tokens * kvb / cluster.node_mem(),
                                1.0)
        p.unique_node_bw = min(rate * ub / (u_nodes * cluster.node_bw()), 1.0)
        p.shared_node_bw = min(rate * sb / cluster.node_bw(), 1.0)
        p.unique_node_mfu = rate * uf / (u_nodes * cluster.node_flops(w.dtype))
        intensity = sf / max(sb, 1.0)
        ridge = cluster.gpu.flops(w.dtype) / cluster.gpu.bw
        p.shared_node_mfu = min(1.0, intensity / ridge) * 0.85
        pts.append(p)
    return pts


def kv_cache_size_fig1a(seq_lens: List[int], llm: LLMSpec = LLMSpec()
                        ) -> Dict[str, List[float]]:
    """Fig. 1a: normalized KV size under common optimization stacks."""
    base = [2 * llm.num_layers * llm.num_heads * llm.head_dim * 2 * s
            for s in seq_lens]  # MHA fp16
    gqa = [b * llm.num_kv_heads / llm.num_heads for b in base]
    gqa_q = [g / 2 for g in gqa]                     # + int8 KV
    gqa_q_sparse = [g * 1.0 for g in gqa_q]          # sparsity: same storage
    return {"MHA fp16": base, "+GQA": gqa, "+quant int8": gqa_q,
            "+sparse (storage unchanged)": gqa_q_sparse}


def bandwidth_scaling_fig1b(batches: List[int], llm: LLMSpec = LLMSpec(),
                            w: Workload = Workload()) -> Dict[str, List[float]]:
    """Fig. 1b: capacity & bandwidth requirement scaling with batch."""
    kvb = llm.kv_bytes_per_token(w.dtype)
    ctx = w.shared_tokens
    return {
        "capacity_no_share": [b * ctx * kvb for b in batches],
        "capacity_shared": [ctx * kvb for _ in batches],
        "bandwidth_no_share": [b * ctx * kvb * w.slo_tokens_per_s
                               for b in batches],
        "bandwidth_shared_gemv": [b * ctx * kvb * w.slo_tokens_per_s
                                  for b in batches],
        "bandwidth_shared_gemm": [ctx * kvb * w.slo_tokens_per_s
                                  for _ in batches],
    }


def size_host_pool_blocks(workset_tokens: float, block_size: int,
                          device_pool_blocks: Optional[int] = None,
                          active_tokens: float = 0.0) -> int:
    """Host-tier sizing heuristic (``--host-pool-blocks auto``).

    The host pool's job is to keep the *prefix working set* — the corpus
    of distinct (corpus, prompt) prefixes the request stream revisits —
    swappable instead of rebuilt. The capacity-model view: the two tiers
    together should hold the working set, so the host tier needs whatever
    the device pool cannot keep resident once the *active* requests'
    unique KV has claimed its share.

      host_blocks = ceil(workset / bs)
                    - max(device_blocks - 1 - ceil(active / bs), 0)

    (the -1 is the reserved null block). With an elastic device pool
    (``device_pool_blocks=None``) the device side grows on demand and
    evicts only under an explicit memory budget, so the conservative
    answer is the full working set — host capacity is cheap relative to
    HBM, and oversizing costs only host RAM.
    """
    if block_size < 1:
        raise ValueError(f"block_size must be >= 1, got {block_size}")
    workset_blocks = math.ceil(max(workset_tokens, 0.0) / block_size)
    if device_pool_blocks is None:
        return workset_blocks
    active_blocks = math.ceil(max(active_tokens, 0.0) / block_size)
    device_resident = max(device_pool_blocks - 1 - active_blocks, 0)
    return max(workset_blocks - device_resident, 0)


def headline_gain(llm: LLMSpec = LLMSpec(), w: Workload = Workload(),
                  cluster: ClusterSpec = ClusterSpec()) -> Dict[str, float]:
    """Max MoSKA gain over each baseline across the Fig. 4 sweep."""
    res = sweep_shared_context(llm=llm, w=w, cluster=cluster)
    moska = {p.shared_tokens: p.throughput for p in res["MoSKA"]}
    gains = {}
    for name, pts in res.items():
        if name == "MoSKA":
            continue
        g = 0.0
        for p in pts:
            if p.throughput > 0:
                g = max(g, moska[p.shared_tokens] / p.throughput)
            elif moska[p.shared_tokens] > 0:
                g = math.inf
        gains[name] = g
    return gains
