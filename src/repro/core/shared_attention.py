"""Shared KV Attention (paper §III.A, Fig. 2a) — the core contribution.

N concurrent query groups that routed to the same shared chunk are gathered
into one (N x d) query matrix and attended against the chunk's KV in a
single GEMM, instead of N memory-bound GEMVs. Mechanically this is an
MoE-style capacity dispatch over *queries* (the inverse of expert dispatch):

    route -> dispatch_plan -> scatter Q to (chunks, capacity, ...)
          -> per-chunk flash GEMM (Pallas kernel on TPU)
          -> gather partial (O, LSE) back per (group, k)
          -> LSE-merge over the k selected chunks.

The merged (O, LSE) is later LSE-merged with the unique-KV partial
(`moska_attention.py`), which is exactly the disaggregated combine of
Fig. 3.

Two implementations:
  * ``shared_attention_batched``  — the MoSKA data path (dispatch + GEMM).
  * ``shared_attention_gather_ref`` — per-request gather oracle (what a
    non-batched system does; used for tests and as the GEMV baseline).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import obs
from repro.core import router as router_lib
from repro.core.shared_kv import SharedKVStore
from repro.sharding import lsc

NEG_INF = -1e30


def _record_dispatch(qmask: jax.Array, keep: jax.Array,
                     layer_idx: Optional[jax.Array] = None) -> None:
    """Dispatch-density metrics (paper's compute-bound claim hinges on
    these): fraction of (chunk, capacity) slots filled, and how many
    (group, k) routes fell off the capacity cliff. Runs inside the jit'd
    decode step, so it goes through the trace-time-gated obs callbacks —
    a no-op unless the serving engine enabled jit metrics.

    ``layer_idx`` (traced scalar, from the layer scan) additionally files
    the utilization under a per-layer histogram
    (``moska/dispatch_capacity_utilization_by_layer/L{i}``) and the
    capacity-cliff drops under a per-layer counter
    (``moska/dropped_queries_by_layer/L{i}``), so routing hot spots —
    and the layers actually losing routes to overflow — are attributable
    individually."""
    if not obs.metrics.JIT_METRICS:
        return
    util = jnp.mean(qmask.astype(jnp.float32))
    dropped = jnp.sum(~keep)
    obs.jit_observe("moska/dispatch_capacity_utilization", util,
                    edges=obs.FRACTION_EDGES)
    if layer_idx is not None:
        obs.jit_observe_per("moska/dispatch_capacity_utilization_by_layer",
                            layer_idx, util, edges=obs.FRACTION_EDGES)
        obs.jit_inc_per("moska/dropped_queries_by_layer", layer_idx, dropped)
    obs.jit_inc("moska/dispatched_queries", jnp.sum(keep))
    obs.jit_inc("moska/dropped_queries", dropped)


class SharedPartial(NamedTuple):
    out: jax.Array     # (G, Q, H, D)
    lse: jax.Array     # (G, Q, H) fp32; -inf where nothing attended


# ---------------------------------------------------------------------------
# per-chunk batched attention (the GEMM) — jnp path; Pallas kernel in
# repro.kernels.shared_chunk_attn is the TPU fast path with identical math.
# ---------------------------------------------------------------------------

def _chunk_batched_attention(qd: jax.Array, k: jax.Array, v: jax.Array,
                             qmask: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """qd: (E, cap, Q, H, D) dispatched queries; k/v: (E, C, KH, D);
    qmask: (E, cap) validity. Non-causal (corpus precedes all queries).

    Returns out (E, cap, Q, H, D), lse (E, cap, Q, H) fp32.
    """
    E, cap, Q, H, D = qd.shape
    KH = k.shape[2]
    G = H // KH
    scale = 1.0 / math.sqrt(D)
    qg = qd.reshape(E, cap, Q, KH, G, D)
    s = jnp.einsum("ecqkgd,eskd->ecqkgs", qg, k,
                   preferred_element_type=jnp.float32) * scale
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("ecqkgs,eskd->ecqkgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    o = o / jnp.maximum(l, 1e-37)[..., None]
    lse = m + jnp.log(jnp.maximum(l, 1e-37))
    lse = jnp.where(qmask[:, :, None, None, None], lse, NEG_INF)
    out = o.reshape(E, cap, Q, H, D).astype(qd.dtype)
    return out, lse.reshape(E, cap, Q, H)


# ---------------------------------------------------------------------------
# the MoSKA path
# ---------------------------------------------------------------------------

def shared_attention_batched(
    q: jax.Array,                  # (G, Q, H, D) query groups (Q=1 decode)
    layer_store_k: jax.Array,      # (E, C, KH, D)
    layer_store_v: jax.Array,      # (E, C, KH, D)
    routing: router_lib.Routing,
    *,
    capacity: Optional[int] = None,
    capacity_factor: float = 2.0,
    kernel: Optional[str] = None,  # None|'jnp'|'pallas'
    block_c: Optional[int] = None,  # kv-tile size for the pallas kernel
    layer_idx: Optional[jax.Array] = None,  # for per-layer dispatch metrics
) -> SharedPartial:
    """Batched Shared KV Attention over routed chunks."""
    G, Q, H, D = q.shape
    E, C, KH, _ = layer_store_k.shape
    K = routing.chunk_ids.shape[1]
    if capacity is None:
        capacity = router_lib.required_capacity(G, K, E, capacity_factor)
    capacity = min(capacity, G * K)

    flat, pos, keep = router_lib.dispatch_plan(routing.chunk_ids, E, capacity)
    # repeat each group's queries K times (request-major slot order)
    q_slots = jnp.repeat(q, K, axis=0)                       # (G*K, Q, H, D)
    drop_pos = jnp.where(keep, pos, capacity)                # OOB => dropped
    qd = jnp.zeros((E, capacity, Q, H, D), q.dtype)
    qd = qd.at[flat, drop_pos].set(q_slots, mode="drop")
    qd = lsc(qd, "chunks", None, None, "heads", None)
    qmask = jnp.zeros((E, capacity), bool).at[flat, drop_pos].set(
        keep, mode="drop")
    _record_dispatch(qmask, keep, layer_idx)

    if kernel == "pallas":
        from repro.kernels import ops as kops
        # kernel takes (E, cap, H, D): fold the per-group query dim into cap
        qd_k = qd.reshape(E, capacity * Q, H, D)
        qm_k = jnp.repeat(qmask, Q, axis=1)
        kern_kwargs = {} if block_c is None else {"block_c": block_c}
        od, lsed = kops.shared_chunk_attention(qd_k, layer_store_k,
                                               layer_store_v, qm_k,
                                               **kern_kwargs)
        od = od.reshape(E, capacity, Q, H, D)
        lsed = lsed.reshape(E, capacity, Q, H)
    else:
        od, lsed = _chunk_batched_attention(qd, layer_store_k, layer_store_v,
                                            qmask)
    # pin the per-chunk GEMM results to the chunk sharding: without this,
    # the multi-pod partitioner replicates the GEMM (gathering the whole
    # store per layer — §Perf multi-pod note)
    od = lsc(od, "chunks", None, None, "heads", None)
    lsed = lsc(lsed, "chunks", None, None, "heads")

    # gather partials back to (G, K, Q, H, ...)
    o_bk = od.at[flat, drop_pos].get(mode="fill", fill_value=0.0)
    l_bk = lsed.at[flat, drop_pos].get(mode="fill", fill_value=NEG_INF)
    l_bk = jnp.where(keep[:, None, None], l_bk, NEG_INF)
    o_bk = o_bk.reshape(G, K, Q, H, D)
    l_bk = l_bk.reshape(G, K, Q, H)

    # LSE-merge over the K selected chunks
    m = jnp.max(l_bk, axis=1)                                # (G, Q, H)
    w = jnp.exp(l_bk - m[:, None])
    denom = jnp.sum(w, axis=1)
    out = jnp.sum(o_bk.astype(jnp.float32) * w[..., None], axis=1)
    out = out / jnp.maximum(denom, 1e-37)[..., None]
    lse = m + jnp.log(jnp.maximum(denom, 1e-37))
    lse = jnp.where(denom > 0, lse, NEG_INF)
    return SharedPartial(out.astype(q.dtype), lse)


# ---------------------------------------------------------------------------
# non-batched oracle / baseline (per-request gather => GEMV-shaped)
# ---------------------------------------------------------------------------

def shared_attention_gather_ref(
    q: jax.Array,                  # (G, Q, H, D)
    layer_store_k: jax.Array,      # (E, C, KH, D)
    layer_store_v: jax.Array,
    routing: router_lib.Routing,
) -> SharedPartial:
    """Per-request chunk gather + attention. Semantically identical to the
    batched path when no capacity drops occur; memory-bound (each request
    re-reads its chunks) — this is the baseline MoSKA's GEMM batching beats.
    """
    G, Q, H, D = q.shape
    E, C, KH, _ = layer_store_k.shape
    K = routing.chunk_ids.shape[1]
    scale = 1.0 / math.sqrt(D)
    ksel = layer_store_k[routing.chunk_ids]                  # (G, K, C, KH, D)
    vsel = layer_store_v[routing.chunk_ids]
    ksel = ksel.reshape(G, K * C, KH, D)
    vsel = vsel.reshape(G, K * C, KH, D)
    qg = q.reshape(G, Q, KH, H // KH, D)
    s = jnp.einsum("gqkhd,gskd->gqkhs", qg, ksel,
                   preferred_element_type=jnp.float32) * scale
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("gqkhs,gskd->gqkhd", p.astype(vsel.dtype), vsel,
                   preferred_element_type=jnp.float32)
    o = o / jnp.maximum(l, 1e-37)[..., None]
    lse = (m + jnp.log(jnp.maximum(l, 1e-37))).reshape(G, Q, H)
    return SharedPartial(o.reshape(G, Q, H, D).astype(q.dtype), lse)
