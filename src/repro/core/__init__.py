"""MoSKA core — the paper's contribution as composable JAX modules.

  shared_kv          persistent shared-KV chunk store ("experts")
  router             training-free top-k chunk routing (inner product)
  shared_attention   the batched GEMM Shared KV Attention + gather oracle
  moska_attention    unique ⊕ shared LSE-merged mixture attention
  disagg             explicit disaggregated (shard_map) execution
  scheduler          continuous batching w/ corpus affinity
  analytical         the paper's §IV analytical performance model
"""
from repro.core.moska_attention import (  # noqa: F401
    MoskaLayerContext, moska_decode_attention, moska_prefill_attention,
)
from repro.core.router import Routing, dispatch_plan, route  # noqa: F401
from repro.core.shared_attention import (  # noqa: F401
    SharedPartial, shared_attention_batched, shared_attention_gather_ref,
)
from repro.core.shared_kv import (  # noqa: F401
    SharedKVStore, abstract_store, build_store, chunk_embeddings,
)
