"""MoE-inspired, training-free chunk router (paper §III.B).

Relevance = inner product between the query and precomputed chunk
embeddings (mean chunk key), exactly the lightweight scheme of
LongHeads/MoBA the paper adopts. Top-k chunks are selected per *query
group* (a single decode token, or a block of prefill queries), so all
queries in a group hit the same chunks and batch into one GEMM.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class Routing(NamedTuple):
    chunk_ids: jax.Array     # (G, K) int32 — selected chunk per query group
    scores: jax.Array        # (G, K) fp32  — router scores of the selection
    full_scores: jax.Array   # (G, E) fp32  — all scores (for diagnostics)


def route(q_group: jax.Array, emb: jax.Array, top_k: int) -> Routing:
    """q_group: (G, H, D) pooled query per group; emb: (E, KH, D).

    Scores are summed over heads after GQA-group alignment: every q head
    scores its kv head's chunk embedding; per-group scalar per chunk.
    """
    G, H, D = q_group.shape
    E, KH, _ = emb.shape
    g = H // KH
    qg = q_group.reshape(G, KH, g, D).astype(jnp.float32)
    scale = 1.0 / math.sqrt(D)
    # (G, KH, g, D) x (E, KH, D) -> (G, E): sum relevance over heads
    s = jnp.einsum("gkhd,ekd->ge", qg, emb.astype(jnp.float32)) * scale
    top_k = min(top_k, E)
    scores, ids = jax.lax.top_k(s, top_k)
    return Routing(ids.astype(jnp.int32), scores, s)


def route_blocks(q: jax.Array, emb: jax.Array, top_k: int,
                 block: int) -> Routing:
    """Prefill routing: pool queries into blocks of ``block`` then route.

    q: (S, H, D) -> groups (S/block, H, D) by mean pooling.
    """
    S, H, D = q.shape
    nb = S // block
    pooled = jnp.mean(q[: nb * block].reshape(nb, block, H, D), axis=1)
    return route(pooled, emb, top_k)


def dispatch_plan(chunk_ids: jax.Array, num_chunks: int,
                  capacity: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Invert routing: for each (group, k) slot compute its position within
    the destination chunk's query batch — the MoE-style capacity dispatch
    that realizes the paper's GEMM batching.

    Returns (flat_chunk, pos_in_chunk, keep) over the flattened (G*K,) slots.
    Slots beyond ``capacity`` are dropped (contribute -inf LSE downstream).
    """
    G, K = chunk_ids.shape
    flat = chunk_ids.reshape(-1)                              # (G*K,)
    onehot = jax.nn.one_hot(flat, num_chunks, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - 1                      # (G*K, E)
    pos = jnp.sum(pos * onehot, axis=1)                       # (G*K,)
    keep = pos < capacity
    return flat, pos, keep


def required_capacity(num_groups: int, top_k: int, num_chunks: int,
                      capacity_factor: float) -> int:
    """Per-chunk query capacity; >= ceil(G*K/E) * cf, MXU-aligned to 8."""
    mean = num_groups * top_k / max(num_chunks, 1)
    cap = int(math.ceil(mean * capacity_factor))
    cap = max(cap, min(num_groups, 8))
    return int(math.ceil(cap / 8) * 8)
