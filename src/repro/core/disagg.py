"""Disaggregated execution of MoSKA attention (paper §III.C, Fig. 3),
rendered JAX-native (DESIGN.md §3).

TPU pods are homogeneous, so the paper's two *node types* become two
*sharding regimes* on one mesh:

  Unique-KV pool   — KV caches sharded batch-major over (pod, data): each
                     device runs the memory-bound GEMV for its own requests
                     and co-locates the FFN (exactly Fig. 3 top).
  Shared-KV pool   — the chunk store sharded chunk-major over (pod, data):
                     each device owns a chunk subset and serves *all*
                     requests' queries for those chunks (Fig. 3 bottom).

The collective schedule made explicit by ``shard_map`` here:

  all-gather(q over chunk axis)        # queries travel to chunk owners
  local routed batched GEMM            # Shared KV Attention on local chunks
  all-reduce LSE-merge (max, then sum) # the disaggregated combine

which is also exactly what pjit emits from the sharding constraints in
``shared_attention_batched`` — this module is the explicit/schedulable
variant used by the serving engine and §Perf experiments.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import MoSKAConfig
from repro.core import router as router_lib
from repro.core import shared_attention as sa

NEG_INF = -1e30


def disaggregated_shared_attention(
    q: jax.Array,              # (B, H, D) decode queries, batch-sharded
    store_k: jax.Array,        # (E, C, KH, D) chunk-sharded over axis
    store_v: jax.Array,
    emb: jax.Array,            # (E, KH, D) chunk-sharded
    cfg: MoSKAConfig,
    mesh: Mesh,
    *,
    chunk_axis: str | Tuple[str, ...] = "data",
    batch_axis: Optional[str | Tuple[str, ...]] = None,
    kernel: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Returns the merged shared partial (out (B,H,D), lse (B,H)) with the
    explicit disaggregated collective schedule."""
    axes = (chunk_axis,) if isinstance(chunk_axis, str) else tuple(chunk_axis)

    def local_fn(q_l, k_l, v_l, emb_l):
        # q_l: (B, H, D) replicated over the chunk axis (all-gathered by the
        # in_spec); k_l/v_l/emb_l: this device's chunk shard.
        E_local = k_l.shape[0]
        topk = min(cfg.top_k_chunks, E_local)
        # route against LOCAL chunks: each owner picks its best-k local
        # chunks per query; the global merge weights partials by true LSE,
        # so locally-routed partials compose exactly like global top-(k*n)
        # routing restricted to per-shard winners (documented deviation:
        # per-shard top-k, the standard distributed-MoE approximation).
        routing = router_lib.route(q_l, emb_l, topk)
        part = sa.shared_attention_batched(
            q_l[:, None], k_l, v_l, routing,
            capacity_factor=cfg.query_capacity_factor, kernel=kernel)
        o_l = part.out[:, 0].astype(jnp.float32)   # (B, H, D)
        lse_l = part.lse[:, 0]                     # (B, H)
        # --- the disaggregated combine: exact LSE merge across owners ---
        m = lse_l
        for ax in axes:
            m = jax.lax.pmax(m, ax)
        w = jnp.where(lse_l > NEG_INF / 2, jnp.exp(lse_l - m), 0.0)
        num = o_l * w[..., None]
        den = w
        for ax in axes:
            num = jax.lax.psum(num, ax)
            den = jax.lax.psum(den, ax)
        out = num / jnp.maximum(den, 1e-37)[..., None]
        lse = jnp.where(den > 0, m + jnp.log(jnp.maximum(den, 1e-37)),
                        NEG_INF)
        return out.astype(q_l.dtype), lse

    cspec = P(chunk_axis)
    return shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(batch_axis), cspec, cspec, cspec),
        out_specs=(P(batch_axis), P(batch_axis)),
        check_rep=False,
    )(q, store_k, store_v, emb)
