"""Logical-axis sharding rules (t5x-style) for the MoSKA framework.

Model code annotates activations with *logical* axis names via ``lsc(x,
"batch", "seq", "heads", ...)``. Launch code installs a rule set mapping
logical names to mesh axes; with no rules installed (unit tests, CPU smoke)
``lsc`` is the identity, so model code never needs a mesh to run.

Rule sets
---------
``TRAIN_RULES``    FSDP + TP: batch over (pod, data); parameter dim-0 /
                   d_model over data (fully-sharded); heads / d_ff / vocab /
                   experts over model.
``SERVE_RULES``    inference: batch over (pod, data); params replicated over
                   data, TP over model; shared KV *chunks* over data (the
                   paper's Shared-KV-node pool); unique KV batch-sharded
                   (the Unique-KV-node pool).
``LONGCTX_RULES``  batch=1 decode: context/chunk parallelism — chunks over
                   (pod, data).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

AxisVal = Union[None, str, Tuple[str, ...]]
LogicalRules = Dict[str, AxisVal]

_state = threading.local()


def set_rules(rules: Optional[LogicalRules]) -> None:
    _state.rules = rules


def current_rules() -> Optional[LogicalRules]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[LogicalRules]):
    prev = current_rules()
    set_rules(rules)
    try:
        yield
    finally:
        set_rules(prev)


def _resolve(rules: LogicalRules, names: Sequence[Optional[str]],
             mesh_axes: Sequence[str],
             shape: Optional[Sequence[int]] = None,
             axis_sizes: Optional[Dict[str, int]] = None) -> P:
    """Resolve logical names to mesh axes; with ``shape`` given, drop any
    axis whose size does not divide the dimension (e.g. 8 kv heads cannot
    shard over model=16 — replicate instead)."""
    out = []
    used: set = set()
    for i, n in enumerate(names):
        if n is None:
            out.append(None)
            continue
        ax = rules.get(n)
        if ax is None:
            out.append(None)
            continue
        cand = tuple(a for a in (ax if isinstance(ax, tuple) else (ax,))
                     if a in mesh_axes and a not in used)
        if shape is not None and axis_sizes is not None:
            kept = []
            size = 1
            for a in cand:
                if shape[i] % (size * axis_sizes[a]) == 0:
                    kept.append(a)
                    size *= axis_sizes[a]
            cand = tuple(kept)
        used.update(cand)
        if not cand:
            out.append(None)
        elif len(cand) == 1:
            out.append(cand[0])
        else:
            out.append(cand)
    return P(*out)


def spec(names: Sequence[Optional[str]],
         rules: Optional[LogicalRules] = None,
         mesh: Optional[jax.sharding.Mesh] = None) -> P:
    """Resolve logical names to a PartitionSpec under the current rules."""
    rules = rules if rules is not None else current_rules()
    if rules is None:
        return P()
    if mesh is None:
        mesh = _current_mesh()
    axes = mesh.axis_names if mesh is not None else ()
    return _resolve(rules, names, axes)


def _current_mesh() -> Optional[jax.sharding.Mesh]:
    try:
        from jax._src import mesh as mesh_lib
        m = mesh_lib.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    return None


def logical_sharding_constraint(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical names; identity w/o rules+mesh."""
    rules = current_rules()
    if rules is None:
        return x
    mesh = _current_mesh()
    if mesh is None:
        return x
    # align names to rank from the right (decode drops leading seq dims)
    if len(names) > x.ndim:
        names = names[len(names) - x.ndim:]
    elif len(names) < x.ndim:
        names = (None,) * (x.ndim - len(names)) + tuple(names)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    ps = _resolve(rules, names, mesh.axis_names, x.shape, sizes)
    return jax.lax.with_sharding_constraint(x, ps)


lsc = logical_sharding_constraint


# ---------------------------------------------------------------------------
# Rule sets
# ---------------------------------------------------------------------------

TRAIN_RULES: LogicalRules = {
    "batch": ("pod", "data"),
    "seq": None,
    "seq_res": None,            # residual-stream seq dim (seqpar variant)
    "kv_seq": None,
    "chunk_seq": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "d_model": None,            # activations keep d_model replicated
    "d_ff": "model",
    "vocab": "model",
    "experts": "model",
    "expert_cap": None,
    "expert_dm": None,
    "chunks": "data",
    "state": "model",
    # parameter logical dims
    "p_dm": "data",             # FSDP: weight d_model dim over data
    "p_heads": "model",
    "p_ff": "model",
    "p_vocab": "model",
    "p_experts": "model",
    "p_inner": "model",
}

SERVE_RULES: LogicalRules = {
    "batch": ("pod", "data"),
    "seq": None,
    "seq_res": None,
    # KV caches / chunk stores shard their *sequence/content* dim over the
    # model axis (flash-decoding KV split): GQA kv_heads (often 8 or 1)
    # cannot shard over model=16, but seq always divides.
    "kv_seq": "model",
    "chunk_seq": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "d_model": None,
    "d_ff": "model",
    "vocab": "model",
    "experts": "model",
    "expert_cap": None,
    "expert_dm": None,
    # shared KV chunk pool over (pod, data) = the Shared-KV node pool;
    # single-pod meshes resolve this to plain data. Replicating per pod
    # instead makes multi-pod XLA re-gather the store every layer (37x
    # collective regression — EXPERIMENTS §Perf multi-pod iteration).
    "chunks": ("pod", "data"),
    "state": "model",
    # weight-stationary serving does not fit >100B models on 16GB chips:
    # serve also shards the d_model weight dim over data (per-layer
    # all-gather inside the scan; see EXPERIMENTS.md §Perf for the cost)
    "p_dm": "data",
    "p_heads": "model",
    "p_ff": "model",
    "p_vocab": "model",
    "p_experts": "model",
    "p_inner": "model",
}

LONGCTX_RULES: LogicalRules = {
    **SERVE_RULES,
    "batch": None,              # batch=1: cannot shard
    "chunks": ("pod", "data"),  # context parallelism over chunks
}


# ---------------------------------------------------------------------------
# §Perf hillclimb variants: named rule overrides applied on top of the
# baseline rule set by launch/dryrun.py --variant <name>. Each encodes one
# hypothesis from EXPERIMENTS.md §Perf.
# ---------------------------------------------------------------------------

VARIANTS: Dict[str, LogicalRules] = {
    # decode: keep weights resident (TP over model only) instead of
    # FSDP-gathering every layer's weights each step — trades per-chip
    # weight memory for zero weight all-gather traffic.
    "weights_resident": {"p_dm": None},
    # MoE decode: experts resident over the *data* axis, expert weight
    # matrices TP-sharded over model — removes the per-layer expert-weight
    # all-gather; dispatch all-to-all routes activations instead.
    "expert_resident": {"p_experts": "data", "experts": "data",
                        "p_dm": "model", "expert_dm": "model"},
    # train: sequence-parallel residual stream — the scan carry (and thus
    # the per-layer saved activation for backward) is sharded over model;
    # attention/FFN re-gather, adding collectives but dividing the dominant
    # activation memory by the model-axis size.
    "seqpar": {"seq_res": "model"},
    # train: combine seqpar with kv_seq sharding of fresh K/V (prefill)
    "seqpar+kv": {"seq_res": "model", "kv_seq": "model"},
    # train: FSDP on the *model-sharded* weight dim instead of d_model —
    # the weight-grad einsum then has the natural partial-over-data ->
    # reduce-scatter strategy (output dim already carries the data axis),
    # instead of gathering global-batch activations (§Perf, mistral it. 3)
    # multi-pod decode: shard the chunk pool over (pod, data) — each pod
    # owns half the chunks (true two-pool disagg) instead of replicating
    # the store per pod and re-gathering it
    "chunks_global": {"chunks": ("pod", "data")},
    "fsdp2": {"p_dm": None,
              "p_ff": ("model", "data"),
              "p_heads": ("model", "data"),
              "p_vocab": ("model", "data"),
              "p_inner": ("model", "data")},
}


def apply_variant(rules: LogicalRules, variant: Optional[str]
                  ) -> LogicalRules:
    if not variant:
        return rules
    out = dict(rules)
    for key in variant.split(","):
        out.update(VARIANTS[key])
    return out


# ---------------------------------------------------------------------------
# Parameter PartitionSpecs
# ---------------------------------------------------------------------------

# Map param leaf names -> logical dim names. Leading scan (layer-stack) dims
# are detected by rank mismatch and mapped to None.
_PARAM_AXES: Dict[str, Tuple[Optional[str], ...]] = {
    "embed": ("p_vocab", None),
    "unembed": ("p_vocab", None),
    "wq": ("p_dm", "p_heads"),
    "wk": ("p_dm", "p_heads"),
    "wv": ("p_dm", "p_heads"),
    "wo": ("p_heads", "p_dm"),
    "bq": ("p_heads",),
    "bk": ("p_heads",),
    "bv": ("p_heads",),
    "w_gate": ("p_dm", "p_ff"),
    "w_up": ("p_dm", "p_ff"),
    "w_down": ("p_ff", "p_dm"),
    "router": ("p_dm", None),
    # experts over model axis (expert parallel); per-expert mats FSDP over
    # data on the d_model dim. d_ff stays local (per-expert FFNs are small).
    "e_gate": ("p_experts", "p_dm", None),
    "e_up": ("p_experts", "p_dm", None),
    "e_down": ("p_experts", None, "p_dm"),
    "scale": (None,),
    "bias": (None,),
    "in_proj": ("p_dm", "p_inner"),
    "out_proj": ("p_inner", "p_dm"),
    "conv_w": (None, "p_inner"),
    "conv_b": ("p_inner",),
    "a_log": ("p_inner",),
    "d_skip": ("p_inner",),
    "dt_bias": ("p_inner",),
    "lru_in": ("p_dm", "p_inner"),
    "lru_out": ("p_inner", "p_dm"),
    "lru_a": ("p_inner",),
    "lru_gate_w": (None, "p_inner"),
    "lru_gate_b": ("p_inner",),
    "pos_embed": (None, None),
}


def param_pspecs(params, rules: LogicalRules, mesh: jax.sharding.Mesh):
    """Build a pytree of PartitionSpec matching ``params``.

    Leaf names are resolved from the last path element; unknown names are
    replicated. Extra leading dims (layer-stack from vmap'd init) map to None.
    """
    axes = mesh.axis_names
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def one(path, leaf):
        name = None
        for p in reversed(path):
            if hasattr(p, "key"):
                name = p.key
                break
        dims = _PARAM_AXES.get(name)
        if dims is None:
            return P()
        pad = leaf.ndim - len(dims)
        names = (None,) * pad + tuple(dims)
        return _resolve(rules, names, axes, leaf.shape, sizes)

    return jax.tree_util.tree_map_with_path(one, params)


def named_sharding_tree(params, rules: LogicalRules, mesh: jax.sharding.Mesh):
    specs = param_pspecs(params, rules, mesh)
    return jax.tree.map(lambda s: jax.sharding.NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
