from repro.sharding.specs import (  # noqa: F401
    LogicalRules, current_rules, logical_sharding_constraint, lsc,
    named_sharding_tree, param_pspecs, set_rules, spec, use_rules,
    SERVE_RULES, TRAIN_RULES, LONGCTX_RULES,
)
