"""Distributed training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --steps 200 --batch 8 --seq 256 [--reduced] [--host-mesh]

On a real TPU slice this runs the same FSDP+TP rules the dry-run proves out
(make_production_mesh); on the CPU container use --reduced --host-mesh for
an end-to-end (if small) distributed run over host devices.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import make_train_batches
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.sharding import TRAIN_RULES, set_rules
from repro.training.train_loop import TrainLoopConfig, train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true",
                    help="use the CPU-smoke reduced config")
    ap.add_argument("--host-mesh", action="store_true",
                    help="mesh over host devices instead of production mesh")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    loop_cfg = TrainLoopConfig(
        num_steps=args.steps, batch_size=args.batch, seq_len=args.seq,
        lr=args.lr, ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every)

    batches = make_train_batches(cfg, args.batch, args.seq)

    if args.host_mesh or jax.device_count() > 1:
        mesh = (make_host_mesh() if args.host_mesh
                else make_production_mesh(multi_pod=args.multi_pod))
        with mesh:
            set_rules(TRAIN_RULES)
            try:
                out = train(cfg, loop_cfg, batches)
            finally:
                set_rules(None)
    else:
        out = train(cfg, loop_cfg, batches)

    final = out["history"][-1] if out["history"] else {}
    print("final:", final)


if __name__ == "__main__":
    main()
