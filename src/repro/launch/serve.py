"""Serving launcher: MoSKA engine over a shared corpus.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --reduced --requests 8 --corpus-tokens 512

Registers a synthetic domain corpus (precomputed shared KV chunks), submits
a stream of requests against it, and reports scheduler/throughput metrics.
On TPU hardware the same engine runs under make_production_mesh with
SERVE_RULES (unique KV batch-sharded = Unique pool; chunks data-sharded =
Shared pool).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.scheduler import wave_stats
from repro.data.pipeline import CorpusSpec, synthesize_corpus
from repro.launch.mesh import make_host_mesh
from repro.models.model import build_model
from repro.serving.engine import EngineConfig, ServingEngine
from repro.sharding import SERVE_RULES, set_rules


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--corpus-tokens", type=int, default=512)
    ap.add_argument("--kernel", default=None, choices=[None, "pallas"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    eng = ServingEngine(cfg, params, EngineConfig(
        max_slots=args.slots, max_seq=args.max_seq, kernel=args.kernel))

    corpus = synthesize_corpus(CorpusSpec(
        "domain-0", args.corpus_tokens, cfg.vocab_size, seed=args.seed))
    t0 = time.perf_counter()
    nchunks = eng.register_corpus("domain-0", corpus)
    print(f"registered corpus domain-0: {nchunks} chunks "
          f"({time.perf_counter()-t0:.1f}s)")

    rng = np.random.default_rng(args.seed)
    for _ in range(args.requests):
        eng.submit(rng.integers(0, cfg.vocab_size,
                                args.prompt_len).tolist(),
                   max_new_tokens=args.new_tokens, corpus_id="domain-0")

    t0 = time.perf_counter()
    done = eng.run()
    wall = time.perf_counter() - t0
    toks = eng.metrics["tokens_generated"]
    print(json.dumps({
        "finished": len(done),
        "tokens": toks,
        "decode_steps": eng.metrics["decode_steps"],
        "tokens_per_s": toks / wall if wall else 0.0,
        "wave": wave_stats(done),
    }, indent=1))


if __name__ == "__main__":
    main()
