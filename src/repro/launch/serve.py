"""Serving launcher: MoSKA engine over a shared corpus.

    PYTHONPATH=src python -m repro.launch.serve --metrics-out metrics.json

Registers a synthetic domain corpus (precomputed shared KV chunks), submits
a stream of requests against it, and reports scheduler/throughput metrics
from the process-global observability registry (``repro.obs``). The default
invocation is the fast dry-run path: a reduced config small enough for CPU
smoke runs; pass ``--full`` for the unreduced architecture. On TPU hardware
the same engine runs under make_production_mesh with SERVE_RULES (unique KV
batch-sharded = Unique pool; chunks data-sharded = Shared pool).

``--metrics-out PATH`` dumps the full registry at exit — scheduler
occupancy/affinity, dispatch capacity-utilization, decode-latency
histograms, and trace spans — as JSON (or line protocol for ``.lp``/
``.txt`` paths). See README "Metrics & tracing" for the naming and bucket
conventions.
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro import obs
from repro.configs import get_config
from repro.core.scheduler import wave_stats
from repro.data.pipeline import CorpusSpec, synthesize_corpus
from repro.launch.mesh import make_host_mesh
from repro.models.model import build_model
from repro.serving.engine import EngineConfig, ServingEngine
from repro.sharding import SERVE_RULES, set_rules


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--full", action="store_true",
                    help="run the unreduced architecture (default: reduced "
                         "dry-run path)")
    ap.add_argument("--reduced", action="store_true",
                    help="deprecated: reduced is now the default")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--corpus-tokens", type=int, default=512)
    ap.add_argument("--kernel", default=None, choices=[None, "pallas"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-donate", action="store_true",
                    help="disable cache donation (copying decode steps; "
                         "for differential debugging)")
    ap.add_argument("--prefill-buckets", default="auto", metavar="SPEC",
                    help="'auto' (default), 'none' (exact lengths), or a "
                         "comma-separated bucket list, e.g. '16,32,64'")
    ap.add_argument("--kv-layout", default="slotted",
                    choices=["slotted", "paged"],
                    help="unique-KV layout: 'slotted' (per-slot max_seq "
                         "slab) or 'paged' (block pool + block tables; "
                         "bit-identical generations, less HBM)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="tokens per KV page (paged layout; must divide "
                         "max-seq)")
    ap.add_argument("--num-blocks", type=int, default=None,
                    help="fixed page-pool size (paged layout; default: "
                         "grow on demand)")
    ap.add_argument("--host-pool-blocks", default="0", metavar="N|auto",
                    help="host memory tier capacity in blocks (paged "
                         "layout): LRU-evicted prefix pages are offloaded "
                         "to host RAM and swapped back on a later hit "
                         "instead of being rebuilt; 0 disables the tier; "
                         "'auto' sizes it from the workload's prefix "
                         "working set via core.analytical."
                         "size_host_pool_blocks")
    ap.add_argument("--prefetch-depth", type=int, default=2,
                    help="max in-flight host->device prefetch transfers "
                         "for predicted next-wave admissions (paged layout "
                         "with a host tier); 0 disables prefetching")
    ap.add_argument("--no-spec-append", action="store_true",
                    help="disable speculative decode-boundary page "
                         "allocation (paged layout; for differential "
                         "debugging — generations are identical either "
                         "way)")
    ap.add_argument("--no-overlap", action="store_true",
                    help="run the wave's host-side bookkeeping after the "
                         "device sync instead of inside the dispatch "
                         "window (for differential debugging / stall "
                         "measurement baselines)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="dump the metrics registry (JSON; .lp/.txt for "
                         "line protocol) at exit")
    ap.add_argument("--metrics-flush-every", type=int, default=0,
                    metavar="N",
                    help="also rewrite --metrics-out atomically every N "
                         "decode waves (streaming export for long serves); "
                         "0 disables")
    args = ap.parse_args(argv)
    if args.metrics_flush_every and not args.metrics_out:
        ap.error("--metrics-flush-every requires --metrics-out")

    if args.host_pool_blocks == "auto":
        if args.kv_layout != "paged":
            ap.error("--host-pool-blocks auto requires --kv-layout paged")
        from repro.core.analytical import size_host_pool_blocks
        host_pool_blocks = size_host_pool_blocks(
            workset_tokens=args.requests * args.prompt_len,
            block_size=args.block_size,
            device_pool_blocks=args.num_blocks,
            active_tokens=args.slots * (args.prompt_len + args.new_tokens))
    else:
        host_pool_blocks = int(args.host_pool_blocks)

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()

    if args.prefill_buckets == "none":
        buckets = None
    elif args.prefill_buckets == "auto":
        buckets = "auto"
    else:
        buckets = [int(b) for b in args.prefill_buckets.split(",")]

    with obs.span("serve.init", arch=args.arch):
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(args.seed))
        eng = ServingEngine(cfg, params, EngineConfig(
            max_slots=args.slots, max_seq=args.max_seq, kernel=args.kernel,
            donate_cache=not args.no_donate, prefill_buckets=buckets,
            kv_layout=args.kv_layout, block_size=args.block_size,
            num_blocks=args.num_blocks,
            host_pool_blocks=host_pool_blocks,
            prefetch_depth=args.prefetch_depth,
            spec_append=not args.no_spec_append,
            overlap_waves=not args.no_overlap))

    exporter = None
    if args.metrics_flush_every:
        exporter = obs.StreamingExporter(args.metrics_out,
                                         every=args.metrics_flush_every)
        eng.wave_hooks.append(exporter.tick)

    corpus = synthesize_corpus(CorpusSpec(
        "domain-0", args.corpus_tokens, cfg.vocab_size, seed=args.seed))
    nchunks = eng.register_corpus("domain-0", corpus)
    reg_span = eng.registry.spans[-1]
    print(f"registered corpus domain-0: {nchunks} chunks "
          f"({reg_span.duration_s:.1f}s)")

    rng = np.random.default_rng(args.seed)
    for _ in range(args.requests):
        eng.submit(rng.integers(0, cfg.vocab_size,
                                args.prompt_len).tolist(),
                   max_new_tokens=args.new_tokens, corpus_id="domain-0")

    done = eng.run()

    reg = eng.registry
    decode_lat = reg.histogram("engine/decode_step_latency_s",
                               obs.LATENCY_EDGES_S)
    summary = {
        "finished": len(done),
        "tokens": int(reg.counter("engine/tokens_generated").value),
        "decode_steps": int(reg.counter("engine/decode_steps").value),
        "tokens_per_s": reg.gauge("engine/last_run_tokens_per_s").value,
        "decode_step_p50_s": decode_lat.quantile(0.5),
        "slot_occupancy": reg.gauge("scheduler/slot_occupancy").value,
        "affinity_hits": reg.counter("scheduler/affinity_hits").value,
        "prefill_buckets": list(eng.prefill_buckets or ()),
        "prefill_compile_count":
            int(reg.gauge("engine/prefill_compile_count").value),
        "decode_cache_bytes_copied":
            reg.gauge("engine/decode_cache_bytes_copied").value,
        "kv_layout": args.kv_layout,
        "hbm_high_water_bytes":
            reg.gauge("engine/hbm_high_water_bytes").value,
        "wave": wave_stats(done),
    }
    if args.kv_layout == "paged":
        summary["host_pool_blocks"] = host_pool_blocks
        summary["swap_in_hits"] = int(
            reg.counter("kvcache/swap_in_hits").value)
        summary["offload_bytes"] = int(
            reg.counter("kvcache/offload_bytes").value)
        summary["offload_admissions"] = int(
            reg.counter("scheduler/offload_admissions").value)
        summary["prefetch_issued"] = int(
            reg.counter("kvcache/prefetch_issued").value)
        summary["prefetch_hits"] = int(
            reg.counter("kvcache/prefetch_hits").value)
        summary["spec_pages_alloc"] = int(
            reg.counter("kvcache/spec_pages_alloc").value)
        summary["decode_stall_sum_s"] = reg.histogram(
            "engine/decode_stall_s", obs.LATENCY_EDGES_S).sum
    if exporter is not None:
        summary["metrics_flushes"] = exporter.flushes
    print(json.dumps(summary, indent=1))
    if args.metrics_out:
        obs.dump(args.metrics_out, reg)
        print(f"metrics registry -> {args.metrics_out}")
    return summary


if __name__ == "__main__":
    main()
