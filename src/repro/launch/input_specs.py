"""Dry-run lowering specs: (architecture x input shape) -> jit-able step
function + ShapeDtypeStruct arguments with NamedShardings (no allocation).

Shape semantics (assignment):
  train_4k     train_step  (loss+grad+AdamW) seq 4096, global batch 256
  prefill_32k  prefill     seq 32768, batch 32 (writes the unique cache)
  decode_32k   serve_step  ONE token, unique KV cache of 32768/request,
               batch 128; MoSKA-enabled archs also carry a 2M-token shared
               store (the paper's feature is first-class at decode)
  long_500k    serve_step  ONE token, 524288-token context, batch 1.
               Dense/VLM archs: the context IS the shared chunk store and
               attention is MoSKA-routed (sub-quadratic — the paper's own
               mechanism); SSM/hybrid: native O(1)-state decode;
               whisper-tiny: SKIPPED (enc-dec, no 500K decode analogue).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import (AUDIO, DENSE, HYBRID, MOE, SSM, VLM,
                                InputShape, INPUT_SHAPES, ModelConfig)
from repro.core.shared_kv import abstract_store
from repro.models.model import Model, build_model
from repro.sharding import specs as sp
from repro.training.optimizer import adamw_init
from repro.training.train_loop import TrainLoopConfig, make_train_step

# tokens in the attached shared store per shape (MoSKA-enabled archs)
DECODE32K_SHARED_TOKENS = 2 * 2**20     # 1024 x 2048-token chunks
LONG500K_UNIQUE_BUF = 2048              # generated-token buffer at 500K


@dataclass
class LoweringSpec:
    arch: str
    shape: str
    fn: Callable                     # positional-args step function
    args: Tuple[Any, ...]            # SDS pytrees with shardings
    rules: sp.LogicalRules
    note: str = ""


class Skip(Exception):
    """(arch, shape) combination is intentionally unsupported."""


def _ns(mesh, pspec):
    return NamedSharding(mesh, pspec)


def _sds(shape, dtype, mesh, pspec):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=_ns(mesh, pspec))


def _shard_tree(tree, pspec_tree, mesh):
    return jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype,
                                          sharding=_ns(mesh, s)),
        tree, pspec_tree,
        is_leaf=lambda x: isinstance(x, P))


def _batch_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _resolve_guarded(rules, names, mesh, shape):
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sp._resolve(rules, names, mesh.axis_names, shape, sizes)


def _abstract_params(model: Model, rules, mesh):
    params = model.abstract_params()
    pspecs = sp.param_pspecs(params, rules, mesh)
    return _shard_tree(params, pspecs, mesh), pspecs


# ---------------------------------------------------------------------------
# cache / store sharding
# ---------------------------------------------------------------------------

_CACHE_AXES: Dict[str, Tuple[Optional[str], ...]] = {
    # dense KVCache fields; seq dim over model = flash-decoding KV split
    "k": (None, "batch", "kv_seq", "kv_heads", None),
    "v": (None, "batch", "kv_seq", "kv_heads", None),
    "length": ("batch",),
    "offset": ("batch",),
    # ssm
    "conv": (None, "batch", None, "state"),
    "state": (None, "batch", None, None, None),
    # hybrid
    "ring_k": (None, "batch", "kv_seq", "kv_heads", None),
    "ring_v": (None, "batch", "kv_seq", "kv_heads", None),
    "ring_pos": (None, "batch", None),
    "lru": (None, "batch", "state"),
    # hybrid conv is (n_rec, B, 3, lw) = same "conv" key
    # whisper
    "self_k": (None, "batch", "kv_seq", "kv_heads", None),
    "self_v": (None, "batch", "kv_seq", "kv_heads", None),
    "cross_k": (None, "batch", "kv_seq", "heads", None),
    "cross_v": (None, "batch", "kv_seq", "heads", None),
}

_STORE_AXES = {
    "k": (None, "chunks", "chunk_seq", "kv_heads", None),
    "v": (None, "chunks", "chunk_seq", "kv_heads", None),
    "emb": (None, "chunks", "kv_heads", None),
    "chunk_positions": (None,),
    "k_scale": (None, "chunks", "chunk_seq", "kv_heads"),
    "v_scale": (None, "chunks", "chunk_seq", "kv_heads"),
}


def _cache_sds(cache, rules, mesh, table=None):
    table = table or _CACHE_AXES

    def one(path, leaf):
        name = None
        for p in reversed(path):
            if hasattr(p, "key") or hasattr(p, "name"):
                name = getattr(p, "key", None) or getattr(p, "name", None)
                break
        names = table.get(name, (None,) * leaf.ndim)
        names = tuple(names[:leaf.ndim]) + (None,) * (leaf.ndim - len(names))
        ps = _resolve_guarded(rules, names, mesh, leaf.shape)
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                    sharding=_ns(mesh, ps))

    return jax.tree_util.tree_map_with_path(one, cache)


def _store_sds(cfg: ModelConfig, shared_tokens: int, rules, mesh):
    store = abstract_store(cfg, shared_tokens)
    return _cache_sds(store._asdict(), rules, mesh, _STORE_AXES), store


# ---------------------------------------------------------------------------
# per-shape builders
# ---------------------------------------------------------------------------

def _train_batch_sds(cfg: ModelConfig, ishape: InputShape, rules, mesh):
    B, S = ishape.global_batch, ishape.seq_len
    bp = _resolve_guarded(rules, ("batch", None), mesh, (B, S))
    batch = {
        "tokens": _sds((B, S), jnp.int32, mesh, bp),
        "targets": _sds((B, S), jnp.int32, mesh, bp),
        "mask": _sds((B, S), jnp.float32, mesh, bp),
    }
    if cfg.family == VLM:
        Pn = cfg.encoder.frontend_seq
        St = S - Pn
        bp2 = _resolve_guarded(rules, ("batch", None), mesh, (B, St))
        batch["tokens"] = _sds((B, St), jnp.int32, mesh, bp2)
        batch["targets"] = _sds((B, St), jnp.int32, mesh, bp2)
        batch["mask"] = _sds((B, St), jnp.float32, mesh, bp2)
        ep = _resolve_guarded(rules, ("batch", None, None), mesh,
                              (B, Pn, cfg.encoder.frontend_dim))
        batch["frontend_embeds"] = _sds((B, Pn, cfg.encoder.frontend_dim),
                                        jnp.bfloat16, mesh, ep)
    elif cfg.family == AUDIO:
        F = cfg.encoder.frontend_seq
        ep = _resolve_guarded(rules, ("batch", None, None), mesh,
                              (B, F, cfg.encoder.frontend_dim))
        batch["frontend_embeds"] = _sds((B, F, cfg.encoder.frontend_dim),
                                        jnp.bfloat16, mesh, ep)
    return batch


def build_train(arch: str, cfg: ModelConfig, ishape: InputShape,
                mesh: Mesh, variant: Optional[str] = None) -> LoweringSpec:
    zero1 = False
    if variant and "zero1" in variant:
        # ZeRO-1: weights TP-only (replicated over data; grads all-reduce
        # naturally), optimizer moments stay fully sharded over data — the
        # one param all-gather per step replaces the pathological per-layer
        # gradient gathers (§Perf, mistral iteration 3)
        zero1 = True
        variant = ",".join(k for k in variant.split(",") if k != "zero1") \
            or None
    rules = sp.apply_variant(sp.TRAIN_RULES, variant)
    model = build_model(cfg)
    if zero1:
        params_rules = sp.apply_variant(rules, "weights_resident")
        params_sds, _ = _abstract_params(model, params_rules, mesh)
        _, opt_pspecs = _abstract_params(model, rules, mesh)
        pspecs = opt_pspecs
        rules = params_rules
    else:
        params_sds, pspecs = _abstract_params(model, rules, mesh)
    opt = jax.eval_shape(adamw_init, params_sds)
    opt_sds = opt._replace(
        step=jax.ShapeDtypeStruct((), jnp.int32, sharding=_ns(mesh, P())),
        mu=_shard_tree(opt.mu, pspecs, mesh),
        nu=_shard_tree(opt.nu, pspecs, mesh))
    batch = _train_batch_sds(cfg, ishape, rules, mesh)
    loop_cfg = TrainLoopConfig(num_steps=1000, remat=True)
    fn = make_train_step(model, loop_cfg)
    return LoweringSpec(arch, ishape.name, fn,
                        (params_sds, opt_sds, batch), rules)


def build_prefill(arch: str, cfg: ModelConfig, ishape: InputShape,
                  mesh: Mesh, variant: Optional[str] = None) -> LoweringSpec:
    rules = sp.apply_variant(sp.SERVE_RULES, variant)
    model = build_model(cfg)
    params_sds, _ = _abstract_params(model, rules, mesh)
    B, S = ishape.global_batch, ishape.seq_len
    if cfg.family == VLM:
        Pn = cfg.encoder.frontend_seq
        toks = _sds((B, S - Pn), jnp.int32, mesh,
                    _resolve_guarded(rules, ("batch", None), mesh,
                                     (B, S - Pn)))
    else:
        toks = _sds((B, S), jnp.int32, mesh,
                    _resolve_guarded(rules, ("batch", None), mesh, (B, S)))
    cache = model.init_cache(B, S, abstract=True)
    cache_sds = _cache_sds(
        cache._asdict() if hasattr(cache, "_asdict") else cache, rules, mesh)
    if hasattr(cache, "_asdict"):
        from repro.kvcache.cache import KVCache
        cache_sds = KVCache(**cache_sds)
    args = [params_sds, toks, cache_sds]
    note = ""
    if cfg.family in (VLM, AUDIO):
        F = cfg.encoder.frontend_seq
        ep = _resolve_guarded(rules, ("batch", None, None), mesh,
                              (B, F, cfg.encoder.frontend_dim))
        fe = _sds((B, F, cfg.encoder.frontend_dim), jnp.bfloat16, mesh, ep)
        fn = lambda p, t, c, f: model.prefill(p, t, c, frontend_embeds=f)
        args.append(fe)
        note = "stub frontend embeddings"
    else:
        fn = lambda p, t, c: model.prefill(p, t, c)
    return LoweringSpec(arch, ishape.name, fn, tuple(args), rules, note)


def build_decode(arch: str, cfg: ModelConfig, ishape: InputShape,
                 mesh: Mesh, variant: Optional[str] = None) -> LoweringSpec:
    long_ctx = ishape.name == "long_500k"
    rules = sp.apply_variant(
        sp.LONGCTX_RULES if long_ctx else sp.SERVE_RULES, variant)
    B = ishape.global_batch
    note = ""

    if long_ctx:
        if cfg.family == AUDIO:
            raise Skip("enc-dec audio has no 500K-token decode analogue "
                       "(DESIGN.md §4)")
        if cfg.family in (DENSE, VLM, MOE):
            if not cfg.moska.enabled:
                raise Skip("full-attention arch without MoSKA routing is "
                           "quadratic at 500K")
            note = ("500K context = MoSKA shared chunk store, routed "
                    "sub-quadratic attention (the paper's mechanism)")

    model = build_model(cfg)
    params_sds, _ = _abstract_params(model, rules, mesh)
    toks = _sds((B,), jnp.int32, mesh,
                _resolve_guarded(rules, ("batch",), mesh, (B,)))

    if long_ctx:
        cache_len = LONG500K_UNIQUE_BUF if cfg.family in (DENSE, VLM, MOE) \
            else ishape.seq_len
        shared_tokens = ishape.seq_len
    else:
        cache_len = ishape.seq_len
        shared_tokens = DECODE32K_SHARED_TOKENS

    cache = model.init_cache(B, cache_len, abstract=True)
    is_nt = hasattr(cache, "_asdict")
    cache_sds = _cache_sds(cache._asdict() if is_nt else cache, rules, mesh)
    if is_nt:
        from repro.kvcache.cache import KVCache
        cache_sds = KVCache(**cache_sds)

    use_store = (cfg.moska.enabled and cfg.family in (DENSE, VLM, MOE)
                 and (long_ctx or True))
    if cfg.family == AUDIO:
        use_store = False   # cross-KV store path exercised in tests/examples
    if cfg.family in (SSM, HYBRID):
        use_store = False

    if use_store:
        store_sds_dict, _ = _store_sds(cfg, shared_tokens, rules, mesh)
        from repro.core.shared_kv import SharedKVStore
        store_sds = SharedKVStore(**store_sds_dict)
        fn = lambda p, t, c, s: model.decode_step(p, t, c, store=s)
        args = (params_sds, toks, cache_sds, store_sds)
        note = note or f"MoSKA store: {shared_tokens} shared tokens"
    else:
        fn = lambda p, t, c: model.decode_step(p, t, c)
        args = (params_sds, toks, cache_sds)
    return LoweringSpec(arch, ishape.name, fn, args, rules, note)


# config-level §Perf variants (vs sharding-rule variants in specs.VARIANTS)
CFG_VARIANTS = {
    "bigblock": dict(attn_block_k=4096),
    "smallblock": dict(attn_block_k=512),
    "remat_dots": dict(remat_policy="dots"),
    "no_remat": dict(remat_policy="none"),
}


def build(arch: str, shape_name: str, mesh: Mesh,
          variant: Optional[str] = None) -> LoweringSpec:
    cfg = get_config(arch)
    rule_keys = []
    if variant:
        for key in variant.split(","):
            if key == "int8store":
                # beyond-paper: int8 shared-KV store (FP8 parity on TPU)
                cfg = dataclasses.replace(cfg, moska=dataclasses.replace(
                    cfg.moska, kv_quant="int8"))
            elif key in CFG_VARIANTS:
                cfg = dataclasses.replace(cfg, **CFG_VARIANTS[key])
            else:
                rule_keys.append(key)
        variant = ",".join(rule_keys) or None
    ishape = INPUT_SHAPES[shape_name]
    if ishape.kind == "train":
        out = build_train(arch, cfg, ishape, mesh, variant=variant)
    elif ishape.kind == "prefill":
        out = build_prefill(arch, cfg, ishape, mesh, variant=variant)
    else:
        out = build_decode(arch, cfg, ishape, mesh, variant=variant)
    return out
