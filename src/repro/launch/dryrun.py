import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent without
hardware.

For every (architecture x input shape) on the 16x16 single-pod mesh AND the
2x16x16 multi-pod mesh:

    with mesh:
        lowered  = jax.jit(step, ...).lower(*input_specs(arch, shape))
        compiled = lowered.compile()
        print(compiled.memory_analysis())   # proves it fits
        print(compiled.cost_analysis())     # FLOPs/bytes for §Roofline

Results (roofline terms, collective histogram, memory) are appended to
results/dryrun/<arch>__<shape>__<mesh>.json so §Roofline and §Perf read
from them.

Usage:
    python -m repro.launch.dryrun --arch llama3-8b --shape decode_32k
    python -m repro.launch.dryrun --all [--multi-pod] [--skip-existing]
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_config
from repro.launch import input_specs as ispecs
from repro.launch.mesh import make_production_mesh
from repro.launch import roofline as rl
from repro.sharding import set_rules

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def run_one(arch: str, shape: str, multi_pod: bool,
            out_dir: str = RESULTS_DIR, verbose: bool = True,
            variant: str | None = None) -> dict:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = 512 if multi_pod else 256
    t0 = time.perf_counter()
    mesh = make_production_mesh(multi_pod=multi_pod)
    record = {"arch": arch, "shape": shape, "mesh": mesh_name,
              "variant": variant, "status": "ok"}
    try:
        with mesh:
            spec = ispecs.build(arch, shape, mesh, variant=variant)
            set_rules(spec.rules)
            try:
                lowered = jax.jit(spec.fn).lower(*spec.args)
                t_lower = time.perf_counter() - t0
                compiled = lowered.compile()
                t_compile = time.perf_counter() - t0 - t_lower
                mem = compiled.memory_analysis()
                if verbose:
                    print(f"[{arch} x {shape} x {mesh_name}] "
                          f"lower {t_lower:.1f}s compile {t_compile:.1f}s")
                    print("  memory_analysis:", mem)
                cost = compiled.cost_analysis()
                if verbose:
                    c = cost[0] if isinstance(cost, list) else cost
                    print("  cost_analysis: flops=%.3e bytes=%.3e" %
                          (c.get("flops", 0), c.get("bytes accessed", 0)))
                hlo = compiled.as_text()
                roof = rl.analyze(
                    compiled, hlo, arch=arch, shape=shape,
                    mesh_name=mesh_name, chips=chips, cfg=get_config(arch),
                    ishape=INPUT_SHAPES[shape], note=spec.note)
                record.update(roofline=roof.to_dict(),
                              lower_s=t_lower, compile_s=t_compile)
                if verbose:
                    print(f"  roofline: compute {roof.compute_s:.3e}s "
                          f"memory {roof.memory_s:.3e}s "
                          f"collective {roof.collective_s:.3e}s "
                          f"-> {roof.dominant}-bound; useful flops "
                          f"{100*roof.useful_flops_ratio:.1f}%")
            finally:
                set_rules(None)
    except ispecs.Skip as e:
        record.update(status="skipped", reason=str(e))
        if verbose:
            print(f"[{arch} x {shape} x {mesh_name}] SKIPPED: {e}")
    except Exception as e:  # a failure here is a bug in the system
        record.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc())
        if verbose:
            print(f"[{arch} x {shape} x {mesh_name}] ERROR: {e}")
    record["wall_s"] = time.perf_counter() - t0
    os.makedirs(out_dir, exist_ok=True)
    vtag = f"__{variant}" if variant else ""
    fname = f"{arch}__{shape}__{mesh_name}{vtag}.json".replace("/", "_")
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(record, f, indent=1, default=str)
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--variant", default=None,
                    help="comma-joined §Perf rule variants "
                         "(see sharding.specs.VARIANTS)")
    ap.add_argument("--out", default=RESULTS_DIR)
    args = ap.parse_args()

    archs = ASSIGNED_ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = 0
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                mesh_name = "2x16x16" if mp else "16x16"
                fname = os.path.join(
                    args.out, f"{arch}__{shape}__{mesh_name}.json")
                if args.skip_existing and os.path.exists(fname):
                    with open(fname) as f:
                        if json.load(f).get("status") in ("ok", "skipped"):
                            continue
                rec = run_one(arch, shape, mp, args.out,
                              variant=args.variant)
                failures += rec["status"] == "error"
    print(f"\ndry-run complete; {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
