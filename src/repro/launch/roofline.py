"""Roofline-term extraction from compiled dry-run artifacts.

Per (arch x shape x mesh):
    compute term    = HLO_FLOPs / peak_FLOP/s          (per chip, seconds)
    memory term     = HLO_bytes / HBM_bw               (per chip, seconds)
    collective term = collective_bytes / link_bw       (per chip, seconds)

``cost_analysis()`` on the SPMD-partitioned executable reports the
*per-device* program, so terms are per-chip directly. collective_bytes is
not in cost_analysis — we parse the optimized HLO and sum the output-buffer
sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops (one-pass per step; conservative single-link model).
"""
from __future__ import annotations

import dataclasses
import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.launch.mesh import HW

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\b")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output-buffer bytes per collective kind from optimized HLO."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        # avoid double counting async -start/-done pairs: count -start only
        rhs = line.split("=", 1)[1]
        if f"{kind}-done" in rhs:
            continue
        out[kind] += _shape_bytes(shape_str)
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_chip: float
    bytes_per_chip: float
    collective_bytes_per_chip: float
    peak_mem_per_chip: float
    collectives: Dict[str, int] = field(default_factory=dict)
    model_flops: float = 0.0           # 6·N·D analytic (global)
    note: str = ""

    @property
    def compute_s(self) -> float:
        return self.flops_per_chip / HW["peak_flops_bf16"]

    @property
    def memory_s(self) -> float:
        return self.bytes_per_chip / HW["hbm_bw"]

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_chip / HW["ici_link_bw"]

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (global HLO flops) — remat/redundancy waste."""
        total = self.flops_per_chip * self.chips
        return self.model_flops / total if total else 0.0

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(compute_s=self.compute_s, memory_s=self.memory_s,
                 collective_s=self.collective_s, dominant=self.dominant,
                 useful_flops_ratio=self.useful_flops_ratio)
        return d


def model_flops_estimate(cfg, ishape) -> float:
    """MODEL_FLOPS: 6·N·D for training (fwd+bwd), 2·N_active·D decode/prefill.
    N counts active params (MoE) excluding embeddings' lookup."""
    n = cfg.active_param_count()
    if ishape.kind == "train":
        tokens = ishape.global_batch * ishape.seq_len
        return 6.0 * n * tokens
    if ishape.kind == "prefill":
        tokens = ishape.global_batch * ishape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * ishape.global_batch  # decode: one token per request


def analyze(compiled, lowered_text: str, *, arch: str, shape: str,
            mesh_name: str, chips: int, cfg=None, ishape=None,
            note: str = "") -> Roofline:
    # while-aware coster (XLA cost_analysis counts scan bodies once;
    # see launch/hlo_cost.py) — terms from the compiled per-device program
    from repro.launch.hlo_cost import analyze_hlo
    cost = analyze_hlo(lowered_text)
    flops = cost.flops
    byts = cost.traffic
    colls = {k: int(v) for k, v in cost.per_collective.items()}
    peak = 0.0
    try:
        ma = compiled.memory_analysis()
        peak = float(getattr(ma, "temp_size_in_bytes", 0) +
                     getattr(ma, "argument_size_in_bytes", 0) +
                     getattr(ma, "output_size_in_bytes", 0) -
                     getattr(ma, "alias_size_in_bytes", 0))
    except Exception:
        pass
    mf = model_flops_estimate(cfg, ishape) if cfg is not None else 0.0
    return Roofline(arch, shape, mesh_name, chips, flops, byts,
                    float(sum(colls.values())), peak, colls, mf, note)


def format_table(rows: List[Roofline]) -> str:
    hdr = (f"{'arch':22s} {'shape':12s} {'mesh':10s} "
           f"{'compute_s':>10s} {'memory_s':>10s} {'coll_s':>10s} "
           f"{'dominant':>10s} {'useful%':>8s} {'mem/chip':>10s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r.arch:22s} {r.shape:12s} {r.mesh:10s} "
            f"{r.compute_s:10.3e} {r.memory_s:10.3e} {r.collective_s:10.3e} "
            f"{r.dominant:>10s} {100*r.useful_flops_ratio:8.1f} "
            f"{r.peak_mem_per_chip/2**30:9.2f}G")
    return "\n".join(lines)
