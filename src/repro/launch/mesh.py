"""Production meshes for the MoSKA deployment target (TPU v5e).

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import; everything else
must see the real device count).
"""
from __future__ import annotations

from typing import Optional

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: Optional[int] = None):
    """Degenerate mesh over whatever devices exist (tests/examples)."""
    n = jax.device_count()
    m = model_axis or 1
    return jax.make_mesh((n // m, m), ("data", "model"))


HW = {
    "name": "tpu-v5e",
    "peak_flops_bf16": 197e12,      # per chip
    "hbm_bw": 819e9,                # per chip, bytes/s
    "ici_link_bw": 50e9,            # per link, bytes/s
    "hbm_bytes": 16e9,              # per chip
    "chips_per_pod": 256,
}
