"""While-loop-aware cost model over optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body ONCE — a
scanned 88-layer model reports 1/88th of its real FLOPs. This module parses
the optimized HLO, builds the computation call graph, and multiplies body
costs by ``known_trip_count`` (emitted by XLA for lax.scan loops), giving
honest roofline terms from the compiled artifact:

  flops            2*M*N*K for dot ops (plus conv), trip-adjusted
  traffic_bytes    operand+output bytes of dot/dus/gather/reduce/collective
                   ops, trip-adjusted (an HBM-traffic proxy: fused
                   elementwise traffic rides along with these anchors)
  collective_bytes output bytes of all-gather/all-reduce/reduce-scatter/
                   all-to-all/collective-permute, trip-adjusted
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+|[\w.\-]+)\s*=\s*(.*)$")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")
_TRIP_RE = re.compile(r'known_trip_count"?\s*[:=]\s*\{"?n"?\s*[:=]\s*"?(\d+)')
_CALLS_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")


def _parse_shapes(s: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(s):
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _shape_bytes(s: str) -> int:
    total = 0
    for dt, dims in _parse_shapes(s):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _numel(dims: List[int]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


@dataclass
class Cost:
    flops: float = 0.0
    traffic: float = 0.0
    collective: float = 0.0
    per_collective: Dict[str, float] = field(default_factory=dict)

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.traffic += o.traffic
        self.collective += o.collective
        for k, v in o.per_collective.items():
            self.per_collective[k] = self.per_collective.get(k, 0.0) + v
        return self

    def scaled(self, f: float) -> "Cost":
        return Cost(self.flops * f, self.traffic * f, self.collective * f,
                    {k: v * f for k, v in self.per_collective.items()})


@dataclass
class _Op:
    name: str
    out_shape: str
    kind: str
    rhs: str


class HloModule:
    def __init__(self, text: str):
        self.computations: Dict[str, List[_Op]] = {}
        self.entry: Optional[str] = None
        self._parse(text)
        self._memo: Dict[str, Cost] = {}

    # ------------------------------------------------------------------
    def _parse(self, text: str):
        cur: Optional[str] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            hdr = _COMP_HDR.match(line.strip())
            if hdr and ("->" in line and line.strip().endswith("{")):
                cur = hdr.group(1)
                self.computations[cur] = []
                if line.strip().startswith("ENTRY"):
                    self.entry = cur
                continue
            if line.strip() == "}":
                cur = None
                continue
            if cur is None:
                continue
            m = _DEF_RE.match(line)
            if not m:
                continue
            rhs = m.group(2)
            # rhs: "<shape> <opkind>(...)" or "(tuple shapes) <opkind>(...)"
            kind_m = re.search(
                r"[\)\]\}]\s*([a-z][a-z0-9\-]*)\(", rhs)
            kind = kind_m.group(1) if kind_m else ""
            shape_end = rhs.find(f" {kind}(") if kind else -1
            out_shape = rhs[:shape_end] if shape_end > 0 else rhs
            self.computations[cur].append(
                _Op(m.group(1).lstrip("%"), out_shape, kind, rhs))
        if self.entry is None and self.computations:
            # entry is typically the last computation in the dump
            self.entry = list(self.computations)[-1]

    # ------------------------------------------------------------------
    def _shape_table(self, comp: str) -> Dict[str, str]:
        return {op.name: op.out_shape for op in self.computations[comp]}

    @staticmethod
    def _arg_names(op: _Op) -> List[str]:
        """Operand names of ``op``, in order. Handles both operand syntaxes
        XLA emits: bare names ``dot(%a, %b)`` and inline-typed
        ``dot(f32[64,256]{1,0} %a, ...)`` (the typed form puts commas
        inside shapes, so naive comma-splitting mis-parses)."""
        args = re.search(r"\b" + re.escape(op.kind) + r"\(([^)]*)\)", op.rhs)
        if not args:
            return []
        body = args.group(1)
        names = re.findall(r"%([\w.\-]+)", body)
        if names:
            return names
        # untyped, un-%-prefixed operand lists: plain comma split is safe
        return [a.strip() for a in body.split(",") if a.strip()]

    def _dot_flops(self, op: _Op, shapes: Dict[str, str]) -> float:
        # flops = 2 * numel(out) * prod(contracting dims of lhs)
        out_shapes = _parse_shapes(op.out_shape)
        if not out_shapes:
            return 0.0
        out_n = _numel(out_shapes[0][1])
        names = self._arg_names(op)
        lhs_name = names[0] if names else None
        cdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rhs)
        k = 1
        if lhs_name and cdims and lhs_name in shapes:
            lhs_shapes = _parse_shapes(shapes[lhs_name])
            if lhs_shapes:
                dims = lhs_shapes[0][1]
                for d in cdims.group(1).split(","):
                    if d and int(d) < len(dims):
                        k *= dims[int(d)]
        return 2.0 * out_n * k

    def _op_args_bytes(self, op: _Op, shapes: Dict[str, str]) -> float:
        return sum(_shape_bytes(shapes[a]) for a in self._arg_names(op)
                   if a in shapes)

    # ------------------------------------------------------------------
    def cost(self, comp: Optional[str] = None) -> Cost:
        comp = comp or self.entry
        if comp in self._memo:
            return self._memo[comp]
        total = Cost()
        shapes = self._shape_table(comp)
        for op in self.computations.get(comp, []):
            kind = op.kind
            if kind == "while":
                trip = 1
                tm = _TRIP_RE.search(op.rhs)
                if tm:
                    trip = int(tm.group(1))
                body = None
                bm = re.search(r"body=%?([\w.\-]+)", op.rhs)
                if bm:
                    body = bm.group(1)
                if body and body in self.computations:
                    total += self.cost(body).scaled(trip)
                cm = _COND_RE.search(op.rhs)
                if cm and cm.group(1) in self.computations:
                    total += self.cost(cm.group(1)).scaled(trip)
                continue
            if kind in ("fusion", "call", "custom-call", "conditional",
                        "map", "reduce", "reduce-window", "sort", "scatter"):
                for cal in _CALLS_RE.findall(op.rhs):
                    if cal in self.computations:
                        total += self.cost(cal)
            if kind in ("dot", "convolution"):
                total += Cost(
                    flops=self._dot_flops(op, shapes),
                    traffic=self._op_args_bytes(op, shapes)
                    + _shape_bytes(op.out_shape))
            elif any(kind.startswith(c) for c in COLLECTIVE_KINDS):
                if kind.endswith("-done"):
                    continue
                base = next(c for c in COLLECTIVE_KINDS
                            if kind.startswith(c))
                b = _shape_bytes(op.out_shape)
                total += Cost(collective=b, traffic=b,
                              per_collective={base: float(b)})
            elif kind == "dynamic-update-slice":
                # in-place write: traffic = update operand read+written,
                # NOT the whole aliased output buffer
                parts = self._arg_names(op)
                upd = (_shape_bytes(shapes[parts[1]])
                       if len(parts) >= 2 and parts[1] in shapes else 0.0)
                total += Cost(traffic=2.0 * upd)
            elif kind == "scatter":
                # like dus: in-place on the aliased operand — count the
                # updates (arg 2) read+written, not the whole buffer
                parts = self._arg_names(op)
                upd = (_shape_bytes(shapes[parts[2]])
                       if len(parts) >= 3 and parts[2] in shapes else 0.0)
                total += Cost(traffic=2.0 * upd)
            elif kind in ("gather", "dynamic-slice", "reduce",
                          "concatenate", "pad", "slice",
                          "select-and-scatter"):
                # traffic anchors: output bytes (= data actually moved);
                # copy/convert/transpose/broadcast/reshape are excluded as
                # they fuse or alias in practice
                total += Cost(traffic=_shape_bytes(op.out_shape))
        self._memo[comp] = total
        return total


def analyze_hlo(text: str) -> Cost:
    return HloModule(text).cost()
