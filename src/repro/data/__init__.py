from repro.data.pipeline import (  # noqa: F401
    CorpusSpec, SyntheticLMDataset, make_train_batches, synthesize_corpus,
)
