"""Data pipeline: synthetic corpora + deterministic LM batch stream.

Two roles:
  * training batches for the train loop (tokens/targets/mask, optional
    modality-stub frontend embeddings for vlm/audio);
  * *shared corpora* for MoSKA serving — long token streams whose KV is
    precomputed into SharedKVStores (the "domain-specific documents" of
    the paper: laws, medical cases, codebases).

Synthetic text is a Zipfian token process with local n-gram structure so
routing is non-degenerate (chunks have distinguishable key statistics).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import AUDIO, VLM, ModelConfig


@dataclass(frozen=True)
class CorpusSpec:
    corpus_id: str
    num_tokens: int
    vocab_size: int
    seed: int = 0
    zipf_a: float = 1.2


def synthesize_corpus(spec: CorpusSpec) -> np.ndarray:
    """Zipfian tokens with drifting local bigram flavour per 1K segment."""
    rng = np.random.default_rng(spec.seed)
    n = spec.num_tokens
    base = rng.zipf(spec.zipf_a, size=n).astype(np.int64)
    base = base % spec.vocab_size
    # per-segment additive offset -> segments (and hence chunks) differ
    seg = 1024
    offs = rng.integers(0, spec.vocab_size, size=(n + seg - 1) // seg)
    idx = np.arange(n) // seg
    return ((base + offs[idx]) % spec.vocab_size).astype(np.int32)


class SyntheticLMDataset:
    """Deterministic, restartable token stream chunked into training rows."""

    def __init__(self, vocab_size: int, seq_len: int, seed: int = 0):
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.seed = seed

    def batches(self, batch_size: int, num_batches: Optional[int] = None
                ) -> Iterator[Dict[str, np.ndarray]]:
        rng = np.random.default_rng(self.seed)
        i = 0
        while num_batches is None or i < num_batches:
            # Zipfian unigrams (learnable marginals => loss descends fast)
            rows = rng.zipf(1.3, size=(batch_size, self.seq_len + 1))
            rows = (rows % self.vocab_size).astype(np.int32)
            # plus copy structure (longer-horizon signal: induction)
            half = self.seq_len // 2
            rows[:, half:half * 2] = rows[:, :half]
            yield {
                "tokens": rows[:, :-1],
                "targets": rows[:, 1:],
                "mask": np.ones((batch_size, self.seq_len), np.float32),
            }
            i += 1


def make_train_batches(cfg: ModelConfig, batch_size: int, seq_len: int,
                       num_batches: Optional[int] = None, seed: int = 0
                       ) -> Iterator[Dict[str, np.ndarray]]:
    """Family-aware batches: adds stub frontend embeddings for vlm/audio
    (the assignment's one allowed stub) and shortens text accordingly."""
    rng = np.random.default_rng(seed + 17)
    if cfg.family == VLM:
        P = min(cfg.encoder.frontend_seq, seq_len // 2)
        ds = SyntheticLMDataset(cfg.vocab_size, seq_len - P, seed)
        for b in ds.batches(batch_size, num_batches):
            b["frontend_embeds"] = rng.standard_normal(
                (batch_size, P, cfg.encoder.frontend_dim)).astype(np.float32)
            yield b
    elif cfg.family == AUDIO:
        F = cfg.encoder.frontend_seq
        ds = SyntheticLMDataset(cfg.vocab_size, seq_len, seed)
        for b in ds.batches(batch_size, num_batches):
            b["frontend_embeds"] = rng.standard_normal(
                (batch_size, F, cfg.encoder.frontend_dim)).astype(np.float32)
            yield b
    else:
        yield from SyntheticLMDataset(cfg.vocab_size, seq_len,
                                      seed).batches(batch_size, num_batches)
