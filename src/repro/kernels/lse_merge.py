"""Pallas TPU kernel: LSE merge of partial attentions.

The combine step of the disaggregated dataflow (Fig. 3): partials from the
Unique-KV path, the routed shared chunks, and remote shards are merged
exactly — softmax over the union of key sets — via exp-weighted averaging
in fp32. Elementwise + row reductions only (VPU work); it exists as a
kernel so the merge can fuse into the collective schedule rather than
bouncing through HBM between partials.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _kernel(o_ref, l_ref, out_ref, lse_ref):
    o = o_ref[...].astype(jnp.float32)           # (P, blk, H, D)
    lse = l_ref[...].astype(jnp.float32)         # (P, blk, H)
    # clamp genuine -inf sentinels to the finite NEG_INF: keeps the
    # all-partials-empty row NaN-free (exp(-inf - -inf) is NaN)
    lse = jnp.maximum(lse, NEG_INF)
    m = jnp.max(lse, axis=0)                     # (blk, H)
    w = jnp.exp(lse - m[None])                   # (P, blk, H)
    denom = jnp.sum(w, axis=0)
    out = jnp.sum(o * w[..., None], axis=0)
    out = out / jnp.maximum(denom, 1e-37)[..., None]
    out_ref[...] = out.astype(out_ref.dtype)
    lse_ref[...] = jnp.where(denom > 0,
                             m + jnp.log(jnp.maximum(denom, 1e-37)), NEG_INF)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def lse_merge(outs: jax.Array, lses: jax.Array, *, block_n: int = 256,
              interpret: bool = True):
    """outs: (P, N, H, D); lses: (P, N, H) -> (out (N,H,D), lse (N,H))."""
    P, N, H, D = outs.shape
    block_n = min(block_n, N)
    nb = pl.cdiv(N, block_n)

    out, lse = pl.pallas_call(
        _kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((P, block_n, H, D), lambda i: (0, i, 0, 0)),
            pl.BlockSpec((P, block_n, H), lambda i: (0, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, H, D), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_n, H), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, H, D), outs.dtype),
            jax.ShapeDtypeStruct((N, H), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
        name="moska_lse_merge",
    )(outs, lses)
    return out, lse
