"""Pallas TPU kernel: MoSKA router chunk scoring.

Relevance of every query group against every shared-chunk embedding —
(G, KH·D) x (E, KH·D)^T as MXU tiles. At corpus scale (16M tokens / 2K
chunk = 8192 chunks) this scoring GEMM is the router's hot loop; top-k
selection stays in XLA (lax.top_k) where it is already optimal.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.compat import CompilerParams


def _kernel(q_ref, e_ref, s_ref, *, scale: float):
    q = q_ref[...].astype(jnp.float32)           # (blk_g, F)
    e = e_ref[...].astype(jnp.float32)           # (blk_e, F)
    s_ref[...] = jax.lax.dot_general(
        q, e, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale


@functools.partial(jax.jit, static_argnames=("block_g", "block_e",
                                             "interpret"))
def router_scores(q: jax.Array, emb: jax.Array, *, block_g: int = 128,
                  block_e: int = 512, interpret: bool = True) -> jax.Array:
    """q: (G, H, D); emb: (E, KH, D) -> scores (G, E) fp32.

    Each query head scores its kv head's embedding (GQA-aligned); summing
    over heads is folded into the contraction by tiling q to (G, KH*g*D)
    and emb to (E, KH*g*D) with the embedding repeated per group head.
    """
    G, H, D = q.shape
    E, KH, _ = emb.shape
    g = H // KH
    scale = 1.0 / math.sqrt(D)
    qf = q.reshape(G, H * D)
    # repeat each kv-head embedding for its g query heads -> (E, H, D)
    ef = jnp.repeat(emb, g, axis=1).reshape(E, H * D)

    block_g = min(block_g, G)
    block_e = min(block_e, E)

    return pl.pallas_call(
        functools.partial(_kernel, scale=scale),
        grid=(pl.cdiv(G, block_g), pl.cdiv(E, block_e)),
        in_specs=[
            pl.BlockSpec((block_g, H * D), lambda i, j: (i, 0)),
            pl.BlockSpec((block_e, H * D), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_g, block_e), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((G, E), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
        name="moska_router_scores",
    )(qf, ef)
