"""Version compatibility shims for Pallas TPU APIs.

jax >= 0.5 renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``;
the kernels target the new name and fall back here on older releases.
"""
from __future__ import annotations

import jax.experimental.pallas.tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams",
                         getattr(pltpu, "TPUCompilerParams", None))
if CompilerParams is None:  # pragma: no cover - very old jax
    raise ImportError("pallas TPU compiler params API not found; "
                      "need jax >= 0.4.30")
