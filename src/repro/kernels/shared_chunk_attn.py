"""Pallas TPU kernel: Shared KV Attention — the paper's GEMM (Fig. 2a).

One grid cell = (shared chunk e, kv head kh, kv tile c). The dispatched
query batch for chunk ``e`` — (cap, G, D), all concurrent requests that
routed here — is multiplied against the chunk's KV tile (C_blk, D) on the
MXU: exactly the memory-bound-GEMV -> compute-bound-GEMM transformation.
Online softmax accumulates across kv tiles in VMEM scratch; the final tile
normalizes and writes (out, lse).

Hardware adaptation (DESIGN.md §3): tiles are MXU-aligned — cap*G and C_blk
are multiples of 128 at production sizes, D=head_dim is the contraction.
VMEM working set per cell ≈ capG*D (q) + C_blk*D*2 (kv) + capG*C_blk (p)
+ capG*(D+2) (scratch) floats; with cap*G=256, C_blk=512, D=128 that is
~1.1 MB — well inside the ~16 MB v5e VMEM budget, leaving room for
double-buffered pipelining.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _kernel(qm_ref, q_ref, k_ref, v_ref, o_ref, lse_ref,
            m_scr, l_scr, acc_scr, *, nc: int, scale: float, tot_c: int):
    c = pl.program_id(2)

    q = q_ref[0, 0].astype(jnp.float32)        # (cap, G, D)
    cap, G, D = q.shape
    qf = q.reshape(cap * G, D)
    k = k_ref[0, :, 0].astype(jnp.float32)      # (C_blk, D)
    v = v_ref[0, :, 0].astype(jnp.float32)      # (C_blk, D)

    @pl.when(c == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    s = jax.lax.dot_general(qf, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    # mask the ragged tail tile (C not a multiple of block_c): OOB padding
    blk = k.shape[0]
    pos = c * blk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < tot_c, s, NEG_INF)
    vpos = c * blk + jax.lax.broadcasted_iota(jnp.int32, v.shape, 0)
    v = jnp.where(vpos < tot_c, v, 0.0)
    m_prev = m_scr[...]                          # (capG, 1)
    m_blk = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_blk)
    p = jnp.exp(s - m_new)                       # (capG, C_blk)
    corr = jnp.exp(m_prev - m_new)               # (capG, 1)
    l_new = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_new = acc_scr[...] * corr + pv
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_new

    @pl.when(c == nc - 1)
    def _finalize():
        qmask = qm_ref[0]                        # (cap,) int32 validity
        l_fin = l_scr[...]
        l_safe = jnp.maximum(l_fin, 1e-37)
        out = (acc_scr[...] / l_safe).reshape(cap, G, D)
        valid = qmask[:, None, None] > 0
        o_ref[0, 0] = jnp.where(valid, out, 0.0).astype(o_ref.dtype)
        lse = (m_scr[...] + jnp.log(l_safe)).reshape(cap, G)
        lse_ref[0, 0] = jnp.where(qmask[:, None] > 0, lse, NEG_INF)


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def shared_chunk_attention(qd: jax.Array, k: jax.Array, v: jax.Array,
                           qmask: jax.Array, *, block_c: int = 512,
                           interpret: bool = True):
    """qd: (E, cap, H, D); k/v: (E, C, KH, D); qmask: (E, cap) bool.

    Returns (out (E, cap, H, D), lse (E, cap, H) fp32). Grid is
    (E, KH, C/block_c); each kv head serves its G = H // KH query heads.
    """
    E, cap, H, D = qd.shape
    _, C, KH, _ = k.shape
    G = H // KH
    block_c = min(block_c, C)
    nc = pl.cdiv(C, block_c)
    scale = 1.0 / math.sqrt(D)

    # regroup queries by kv head: (E, KH, cap, G, D)
    qg = qd.reshape(E, cap, KH, G, D).transpose(0, 2, 1, 3, 4)
    qm = qmask.astype(jnp.int32)

    grid = (E, KH, nc)
    out, lse = pl.pallas_call(
        functools.partial(_kernel, nc=nc, scale=scale, tot_c=C),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, cap), lambda e, h, c: (e, 0)),
            pl.BlockSpec((1, 1, cap, G, D), lambda e, h, c: (e, h, 0, 0, 0)),
            pl.BlockSpec((1, block_c, 1, D), lambda e, h, c: (e, c, h, 0)),
            pl.BlockSpec((1, block_c, 1, D), lambda e, h, c: (e, c, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, cap, G, D), lambda e, h, c: (e, h, 0, 0, 0)),
            pl.BlockSpec((1, 1, cap, G), lambda e, h, c: (e, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((E, KH, cap, G, D), qd.dtype),
            jax.ShapeDtypeStruct((E, KH, cap, G), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((cap * G, 1), jnp.float32),
            pltpu.VMEM((cap * G, 1), jnp.float32),
            pltpu.VMEM((cap * G, D), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="moska_shared_chunk_attn",
    )(qm, qg, k, v)

    out = out.transpose(0, 2, 1, 3, 4).reshape(E, cap, H, D)
    lse = lse.transpose(0, 2, 1, 3).reshape(E, cap, H)
    return out, lse


# ---------------------------------------------------------------------------
# int8-quantized shared store (beyond-paper; FP8 parity on TPU): the kernel
# reads int8 KV tiles from HBM (half the bandwidth of bf16) and dequantizes
# in-register inside VMEM — the XLA/jnp path cannot express this fusion.
# ---------------------------------------------------------------------------

def _kernel_q8(qm_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref, lse_ref,
               m_scr, l_scr, acc_scr, *, nc: int, scale: float, tot_c: int):
    c = pl.program_id(2)

    q = q_ref[0, 0].astype(jnp.float32)        # (cap, G, D)
    cap, G, D = q.shape
    qf = q.reshape(cap * G, D)
    # in-register dequantization of the int8 tiles
    ksc = ks_ref[0, :, 0].astype(jnp.float32)   # (C_blk,)
    vsc = vs_ref[0, :, 0].astype(jnp.float32)
    k = k_ref[0, :, 0].astype(jnp.float32) * ksc[:, None]
    v = v_ref[0, :, 0].astype(jnp.float32) * vsc[:, None]

    @pl.when(c == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    s = jax.lax.dot_general(qf, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    blk = k.shape[0]
    pos = c * blk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < tot_c, s, NEG_INF)
    vpos = c * blk + jax.lax.broadcasted_iota(jnp.int32, v.shape, 0)
    v = jnp.where(vpos < tot_c, v, 0.0)
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(c == nc - 1)
    def _finalize():
        qmask = qm_ref[0]
        l_safe = jnp.maximum(l_scr[...], 1e-37)
        out = (acc_scr[...] / l_safe).reshape(cap, G, D)
        valid = qmask[:, None, None] > 0
        o_ref[0, 0] = jnp.where(valid, out, 0.0).astype(o_ref.dtype)
        lse = (m_scr[...] + jnp.log(l_safe)).reshape(cap, G)
        lse_ref[0, 0] = jnp.where(qmask[:, None] > 0, lse, NEG_INF)


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def shared_chunk_attention_q8(qd: jax.Array, k: jax.Array, v: jax.Array,
                              k_scale: jax.Array, v_scale: jax.Array,
                              qmask: jax.Array, *, block_c: int = 512,
                              interpret: bool = True):
    """int8 variant. k/v: (E, C, KH, D) int8; scales: (E, C, KH) f32."""
    E, cap, H, D = qd.shape
    _, C, KH, _ = k.shape
    G = H // KH
    block_c = min(block_c, C)
    nc = pl.cdiv(C, block_c)
    scale = 1.0 / math.sqrt(D)
    qg = qd.reshape(E, cap, KH, G, D).transpose(0, 2, 1, 3, 4)
    qm = qmask.astype(jnp.int32)

    out, lse = pl.pallas_call(
        functools.partial(_kernel_q8, nc=nc, scale=scale, tot_c=C),
        grid=(E, KH, nc),
        in_specs=[
            pl.BlockSpec((1, cap), lambda e, h, c: (e, 0)),
            pl.BlockSpec((1, 1, cap, G, D), lambda e, h, c: (e, h, 0, 0, 0)),
            pl.BlockSpec((1, block_c, 1, D), lambda e, h, c: (e, c, h, 0)),
            pl.BlockSpec((1, block_c, 1, D), lambda e, h, c: (e, c, h, 0)),
            pl.BlockSpec((1, block_c, 1), lambda e, h, c: (e, c, h)),
            pl.BlockSpec((1, block_c, 1), lambda e, h, c: (e, c, h)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, cap, G, D), lambda e, h, c: (e, h, 0, 0, 0)),
            pl.BlockSpec((1, 1, cap, G), lambda e, h, c: (e, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((E, KH, cap, G, D), jnp.bfloat16),
            jax.ShapeDtypeStruct((E, KH, cap, G), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((cap * G, 1), jnp.float32),
            pltpu.VMEM((cap * G, 1), jnp.float32),
            pltpu.VMEM((cap * G, D), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="moska_shared_chunk_attn_q8",
    )(qm, qg, k, v, k_scale, v_scale)

    out = out.transpose(0, 2, 1, 3, 4).reshape(E, cap, H, D)
    lse = lse.transpose(0, 2, 1, 3).reshape(E, cap, H)
    return out, lse
