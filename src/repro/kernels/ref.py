"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are property-tested against
(tests/test_kernels.py sweeps shapes/dtypes with assert_allclose).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def shared_chunk_attention_ref(qd: jax.Array, k: jax.Array, v: jax.Array,
                               qmask: jax.Array
                               ) -> Tuple[jax.Array, jax.Array]:
    """The batched per-chunk GEMM attention (paper Fig. 2a).

    qd: (E, cap, H, D) dispatched queries; k/v: (E, C, KH, D);
    qmask: (E, cap) bool. Non-causal. Returns (out (E,cap,H,D),
    lse (E,cap,H) fp32; -inf rows where qmask is False).
    """
    E, cap, H, D = qd.shape
    KH = k.shape[2]
    G = H // KH
    scale = 1.0 / math.sqrt(D)
    qg = qd.reshape(E, cap, KH, G, D)
    s = jnp.einsum("eckgd,eskd->eckgs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("eckgs,eskd->eckgd", p, v.astype(jnp.float32))
    o = o / jnp.maximum(l, 1e-37)[..., None]
    lse = m + jnp.log(jnp.maximum(l, 1e-37))
    lse = jnp.where(qmask[:, :, None, None], lse, NEG_INF)
    out = jnp.where(qmask[:, :, None, None, None], o, 0.0)
    return (out.reshape(E, cap, H, D).astype(qd.dtype),
            lse.reshape(E, cap, H))


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         kv_len: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Unique-KV decode GEMV. q: (B, H, D); k/v: (B, S, KH, D);
    kv_len: (B,). Returns (out (B,H,D), lse (B,H) fp32)."""
    B, H, D = q.shape
    S, KH = k.shape[1], k.shape[2]
    G = H // KH
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, KH, G, D)
    s = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    mask = jnp.arange(S)[None] < kv_len[:, None]
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    o = o / jnp.maximum(l, 1e-37)[..., None]
    lse = m + jnp.log(jnp.maximum(l, 1e-37))
    return o.reshape(B, H, D).astype(q.dtype), lse.reshape(B, H)


def lse_merge_ref(outs: jax.Array, lses: jax.Array
                  ) -> Tuple[jax.Array, jax.Array]:
    """Merge P partial attentions. outs: (P, N, H, D); lses: (P, N, H).
    Exact: equals softmax over the union of key sets."""
    lses = lses.astype(jnp.float32)
    m = jnp.max(lses, axis=0)
    w = jnp.exp(lses - m[None])
    denom = jnp.sum(w, axis=0)
    out = jnp.sum(outs.astype(jnp.float32) * w[..., None], axis=0)
    out = out / jnp.maximum(denom, 1e-37)[..., None]
    lse = jnp.where(denom > 0, m + jnp.log(jnp.maximum(denom, 1e-37)),
                    NEG_INF)
    return out.astype(outs.dtype), lse


def router_scores_ref(q: jax.Array, emb: jax.Array) -> jax.Array:
    """q: (G, H, D); emb: (E, KH, D) -> (G, E) fp32 relevance scores."""
    G, H, D = q.shape
    E, KH, _ = emb.shape
    g = H // KH
    qg = q.reshape(G, KH, g, D).astype(jnp.float32)
    return jnp.einsum("gkhd,ekd->ge", qg,
                      emb.astype(jnp.float32)) / math.sqrt(D)
