"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True (CPU container validates kernel bodies in
Python); on a real TPU deployment set ``repro.kernels.ops.INTERPRET = False``
or pass interpret=False explicitly — the kernels are written for the TPU
target (BlockSpec VMEM tiling, MXU-aligned tiles).
"""
from __future__ import annotations

import jax

from repro.kernels.decode_attn import decode_attention as _decode
from repro.kernels.lse_merge import lse_merge as _merge
from repro.kernels.paged_decode_attn import (
    paged_decode_attention as _paged_decode)
from repro.kernels.router_score import router_scores as _router
from repro.kernels.shared_chunk_attn import (
    shared_chunk_attention as _shared)

INTERPRET = True


def shared_chunk_attention(qd, k, v, qmask, *, block_c: int = 512,
                           interpret: bool | None = None):
    it = INTERPRET if interpret is None else interpret
    return _shared(qd, k, v, qmask, block_c=block_c, interpret=it)


def decode_attention(q, k, v, kv_len, *, block_s: int = 1024,
                     interpret: bool | None = None):
    it = INTERPRET if interpret is None else interpret
    return _decode(q, k, v, kv_len, block_s=block_s, interpret=it)


def paged_decode_attention(q, k_pool, v_pool, table, kv_len, *,
                           interpret: bool | None = None):
    it = INTERPRET if interpret is None else interpret
    return _paged_decode(q, k_pool, v_pool, table, kv_len, interpret=it)


def lse_merge(outs, lses, *, block_n: int = 256,
              interpret: bool | None = None):
    it = INTERPRET if interpret is None else interpret
    return _merge(outs, lses, block_n=block_n, interpret=it)


def router_scores(q, emb, *, block_g: int = 128, block_e: int = 512,
                  interpret: bool | None = None):
    it = INTERPRET if interpret is None else interpret
    return _router(q, emb, block_g=block_g, block_e=block_e, interpret=it)
