"""Pallas TPU kernel: unique-KV decode attention (flash-decoding GEMV).

This is the paper's memory-bound path (Fig. 2a left): one query per request
against its private KV cache. The kernel tiles the cache sequence into
(block_s, D) VMEM blocks — grid (batch, kv_head, seq tile) — with online-
softmax accumulation in scratch and ragged masking from per-request
``kv_len``. It exists to keep the Unique-KV node honest/fast; the roofline
contrast between this kernel (intensity ~G) and `shared_chunk_attn`
(intensity ~cap·G) is the paper's core claim, measured in
benchmarks/bench_kernels.py.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, lse_ref,
            m_scr, l_scr, acc_scr, *, ns: int, block_s: int, scale: float):
    s_idx = pl.program_id(2)

    q = q_ref[0, 0].astype(jnp.float32)          # (G, D)
    k = k_ref[0, :, 0].astype(jnp.float32)       # (block_s, D)
    v = v_ref[0, :, 0].astype(jnp.float32)

    @pl.when(s_idx == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    kv_len = len_ref[0]
    pos = s_idx * block_s + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = pos < kv_len
    s = jnp.where(valid, s, NEG_INF)
    # zero V on invalid rows: OOB tile padding must not produce 0*NaN
    vpos = s_idx * block_s + jax.lax.broadcasted_iota(jnp.int32, v.shape, 0)
    v = jnp.where(vpos < kv_len, v, 0.0)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(s_idx == ns - 1)
    def _finalize():
        l_safe = jnp.maximum(l_scr[...], 1e-37)
        o_ref[0, 0] = (acc_scr[...] / l_safe).astype(o_ref.dtype)
        lse_ref[0, 0] = (m_scr[...] + jnp.log(l_safe))[:, 0]


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     kv_len: jax.Array, *, block_s: int = 1024,
                     interpret: bool = True):
    """q: (B, H, D); k/v: (B, S, KH, D); kv_len: (B,) valid lengths.

    Returns (out (B, H, D), lse (B, H) fp32).
    """
    B, H, D = q.shape
    _, S, KH, _ = k.shape
    G = H // KH
    block_s = min(block_s, S)
    ns = pl.cdiv(S, block_s)
    scale = 1.0 / math.sqrt(D)

    qg = q.reshape(B, KH, G, D)
    lens = kv_len.astype(jnp.int32)

    out, lse = pl.pallas_call(
        functools.partial(_kernel, ns=ns, block_s=block_s, scale=scale),
        grid=(B, KH, ns),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, s: (b,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, G, D), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, block_s, 1, D), lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((1, block_s, 1, D), lambda b, h, s: (b, s, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, G), lambda b, h, s: (b, h, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, KH, G, D), q.dtype),
            jax.ShapeDtypeStruct((B, KH, G), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="moska_unique_decode_attn",
    )(lens, qg, k, v)

    return out.reshape(B, H, D), lse.reshape(B, H)
