"""Pallas TPU kernel: paged unique-KV decode attention.

Same flash-decoding GEMV as ``kernels/decode_attn.py``, but K/V live in a
shared block pool ``(N, block_size, KH, D)`` instead of per-request
``max_seq`` slabs; each request's pages are named by a block table
``(B, M)``. The table and the ragged lengths ride in as **scalar-prefetch
operands** (``pltpu.PrefetchScalarGridSpec``), so the K/V BlockSpec
index_map dereferences ``table[b, m]`` to pick the physical pool page for
grid step ``(b, h, m)`` — the kernel itself never materialises a gathered
contiguous cache, which is the point: HBM traffic is one page per grid
step regardless of how fragmented the mapping is.

``paged_decode_attention_ref`` is the jnp oracle: gather the pool through
the table into a contiguous ``(B, M * bs, KH, D)`` view and run the dense
``kernels.ref.decode_attention_ref``. Null-page garbage past ``kv_len``
is masked to exact-zero probability, so the oracle is *bitwise* equal to
the dense reference on an equivalently-filled slotted cache — the
engine's paged/slotted bit-identity rests on this (see
tests/test_kernels.py).
"""
from __future__ import annotations

import functools
import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels import ref
from repro.kernels.compat import CompilerParams
from repro.kvcache.paged import gather_layer

NEG_INF = -1e30


def paged_decode_attention_ref(q: jax.Array, k_pool: jax.Array,
                               v_pool: jax.Array, table: jax.Array,
                               kv_len: jax.Array
                               ) -> Tuple[jax.Array, jax.Array]:
    """jnp oracle: table gather + dense decode reference.

    q: (B, H, D); k_pool/v_pool: (N, bs, KH, D); table: (B, M) int32;
    kv_len: (B,). Returns (out (B, H, D), lse (B, H) fp32).
    """
    k = gather_layer(k_pool, table)
    v = gather_layer(v_pool, table)
    return ref.decode_attention_ref(q, k, v, kv_len)


def _kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref, lse_ref,
            m_scr, l_scr, acc_scr, *, nm: int, bs: int, scale: float):
    b_idx = pl.program_id(0)
    m_idx = pl.program_id(2)

    q = q_ref[0, 0].astype(jnp.float32)          # (G, D)
    k = k_ref[0, :, 0].astype(jnp.float32)       # (bs, D) — one pool page
    v = v_ref[0, :, 0].astype(jnp.float32)

    @pl.when(m_idx == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    kv_len = len_ref[b_idx]
    # logical positions of this page: the m-th table entry covers
    # [m*bs, (m+1)*bs) regardless of which physical page backs it
    pos = m_idx * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < kv_len, s, NEG_INF)
    # zero V on masked rows: null-page garbage must not produce 0*NaN
    vpos = m_idx * bs + jax.lax.broadcasted_iota(jnp.int32, v.shape, 0)
    v = jnp.where(vpos < kv_len, v, 0.0)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(m_idx == nm - 1)
    def _finalize():
        l_safe = jnp.maximum(l_scr[...], 1e-37)
        o_ref[0, 0] = (acc_scr[...] / l_safe).astype(o_ref.dtype)
        lse_ref[0, 0] = (m_scr[...] + jnp.log(l_safe))[:, 0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(q: jax.Array, k_pool: jax.Array,
                           v_pool: jax.Array, table: jax.Array,
                           kv_len: jax.Array, *, interpret: bool = True
                           ) -> Tuple[jax.Array, jax.Array]:
    """q: (B, H, D); k_pool/v_pool: (N, bs, KH, D) physical page pools;
    table: (B, M) int32 block tables (NULL-padded); kv_len: (B,).

    Grid (B, KH, M): the m-th sequence tile of request b reads pool page
    ``table[b, m]`` directly via the scalar-prefetched index_map.
    Returns (out (B, H, D), lse (B, H) fp32).
    """
    B, H, D = q.shape
    N, bs, KH, _ = k_pool.shape
    M = table.shape[1]
    G = H // KH
    scale = 1.0 / math.sqrt(D)

    qg = q.reshape(B, KH, G, D)
    tbl = table.astype(jnp.int32)
    lens = kv_len.astype(jnp.int32)

    def kv_spec():
        # page index comes from the prefetched table, not the grid
        return pl.BlockSpec((1, bs, 1, D),
                            lambda b, h, m, tbl, lens: (tbl[b, m], 0, h, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KH, M),
        in_specs=[
            pl.BlockSpec((1, 1, G, D),
                         lambda b, h, m, tbl, lens: (b, h, 0, 0)),
            kv_spec(),
            kv_spec(),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, G, D),
                         lambda b, h, m, tbl, lens: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, G),
                         lambda b, h, m, tbl, lens: (b, h, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )

    out, lse = pl.pallas_call(
        functools.partial(_kernel, nm=M, bs=bs, scale=scale),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, KH, G, D), q.dtype),
            jax.ShapeDtypeStruct((B, KH, G), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="moska_paged_decode_attn",
    )(tbl, lens, qg, k_pool, v_pool)

    return out.reshape(B, H, D), lse.reshape(B, H)
