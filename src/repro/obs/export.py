"""Exporters for the metrics registry: JSON and influx-style line protocol.

JSON is the round-trippable format (``to_dict`` / ``from_dict`` /
``dump`` / ``load``); line protocol is a one-way flat text dump for
grep/ingest pipelines. ``--metrics-out foo.json`` on the serving launcher
goes through :func:`dump`.
"""
from __future__ import annotations

import json
import os
from typing import List, Optional

from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               get_registry)

SCHEMA_VERSION = 1


def to_dict(reg: Optional[MetricsRegistry] = None) -> dict:
    reg = reg if reg is not None else get_registry()
    return {
        "schema_version": SCHEMA_VERSION,
        "metrics": reg.snapshot(),
        "spans": [s.snapshot() for s in reg.spans],
    }


def to_json(reg: Optional[MetricsRegistry] = None, indent: int = 1) -> str:
    return json.dumps(to_dict(reg), indent=indent, sort_keys=True)


def from_dict(d: dict) -> MetricsRegistry:
    """Rebuild a registry from :func:`to_dict` output (exporter round-trip).
    Spans come back as plain Span objects with their recorded times."""
    from repro.obs.trace import Span

    if d.get("schema_version") != SCHEMA_VERSION:
        raise ValueError(f"unsupported metrics schema: "
                         f"{d.get('schema_version')!r}")
    reg = MetricsRegistry()
    for name, snap in d.get("metrics", {}).items():
        kind = snap.get("kind")
        if kind == "counter":
            reg.counter(name).value = float(snap["value"])
        elif kind == "gauge":
            g = reg.gauge(name)
            g.value = float(snap["value"])
            g.min, g.max = snap.get("min"), snap.get("max")
            g.updates = int(snap.get("updates", 0))
        elif kind == "histogram":
            h = reg.histogram(name, snap["edges"])
            h.counts = [int(c) for c in snap["counts"]]
            h.count = int(snap["count"])
            h.sum = float(snap["sum"])
            h.min, h.max = snap.get("min"), snap.get("max")
        else:
            raise ValueError(f"unknown metric kind {kind!r} for {name!r}")
    for s in d.get("spans", []):
        reg.spans.append(Span(s["name"], s["start_s"], s["end_s"],
                              s.get("parent"), s.get("depth", 0),
                              dict(s.get("attrs", {}))))
    return reg


def to_lines(reg: Optional[MetricsRegistry] = None) -> List[str]:
    """Flat line-protocol dump: ``name[,tag=v] field=value ...`` per line.
    Histograms expand to one ``le=<edge>`` line per bucket plus a summary
    line; spans emit ``span,name=<n>,parent=<p> duration_s=<d>``."""
    reg = reg if reg is not None else get_registry()
    lines: List[str] = []
    for name in reg.names():
        m = reg.get(name)
        key = name.replace(" ", "_")
        if isinstance(m, Counter):
            lines.append(f"{key} value={m.value}")
        elif isinstance(m, Gauge):
            lines.append(f"{key} value={m.value} min={m.min} max={m.max}")
        elif isinstance(m, Histogram):
            for edge, c in zip(m.edges, m.counts):
                lines.append(f"{key},le={edge} count={c}")
            lines.append(f"{key},le=+inf count={m.counts[-1]}")
            lines.append(f"{key} count={m.count} sum={m.sum} mean={m.mean}")
    for s in reg.spans:
        lines.append(f"span,name={s.name},parent={s.parent},depth={s.depth} "
                     f"duration_s={s.duration_s}")
    return lines


def dump(path: str, reg: Optional[MetricsRegistry] = None,
         atomic: bool = False) -> None:
    """Write the registry to ``path``: JSON unless the extension is
    ``.lp``/``.txt`` (line protocol). With ``atomic`` the body lands via
    a same-directory temp file + ``os.replace``, so a concurrent reader
    never sees a torn dump — the streaming exporter's mode."""
    if path.endswith((".lp", ".txt")):
        body = "\n".join(to_lines(reg)) + "\n"
    else:
        body = to_json(reg) + "\n"
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    target = f"{path}.tmp" if atomic else path
    with open(target, "w") as f:
        f.write(body)
    if atomic:
        os.replace(target, path)


class StreamingExporter:
    """Periodic registry flusher for long-running serves.

    ``tick()`` once per decode wave; every ``every``-th tick rewrites
    ``path`` with the current registry state (atomically, so a tailing
    reader never sees a torn file). The final ``flush()`` at exit is the
    caller's job — the launcher's ``--metrics-out`` dump doubles as it.

    Wired by ``launch/serve --metrics-flush-every N`` through the
    engine's ``wave_hooks`` (host-side callbacks at the end of each
    wave), so a stuck or hours-long serve is observable mid-flight
    instead of only post-mortem.
    """

    def __init__(self, path: str, every: int = 1,
                 reg: Optional[MetricsRegistry] = None):
        if every < 1:
            raise ValueError(f"flush interval must be >= 1, got {every}")
        self.path = path
        self.every = int(every)
        self._reg = reg
        self.ticks = 0
        self.flushes = 0

    def tick(self) -> bool:
        """Count one wave; flush when the interval elapses. Returns
        whether this tick flushed."""
        self.ticks += 1
        if self.ticks % self.every:
            return False
        self.flush()
        return True

    def flush(self) -> None:
        dump(self.path, self._reg, atomic=True)
        self.flushes += 1


def load(path: str) -> MetricsRegistry:
    with open(path) as f:
        return from_dict(json.load(f))
