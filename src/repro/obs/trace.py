"""Wall-clock trace spans with parent nesting.

``span("engine.decode_step", wave=3)`` measures a wall-clock interval and
records it — with its parent span and nesting depth — into the active
:class:`~repro.obs.metrics.MetricsRegistry`. Spans are host-side only (they
time Python control flow, not device execution); wrap the device sync point
(``np.asarray`` / ``block_until_ready``) inside the span to capture device
time. Nesting is tracked per thread.
"""
from __future__ import annotations

import contextlib
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, Iterator, Optional, Union

from repro.obs import metrics as M


@dataclass
class Span:
    name: str
    start_s: float                      # perf_counter timestamp
    end_s: float = 0.0
    parent: Optional[str] = None
    depth: int = 0
    attrs: Dict[str, Union[int, float, str]] = field(default_factory=dict)

    @property
    def duration_s(self) -> float:
        return self.end_s - self.start_s

    def snapshot(self) -> dict:
        return {"name": self.name, "start_s": self.start_s,
                "end_s": self.end_s, "duration_s": self.duration_s,
                "parent": self.parent, "depth": self.depth,
                "attrs": dict(self.attrs)}


_tls = threading.local()


def _stack() -> list:
    st = getattr(_tls, "stack", None)
    if st is None:
        st = _tls.stack = []
    return st


def current_span() -> Optional[Span]:
    st = _stack()
    return st[-1] if st else None


@contextlib.contextmanager
def span(name: str, registry: Optional[M.MetricsRegistry] = None,
         record_histogram: bool = True,
         **attrs: Union[int, float, str]) -> Iterator[Span]:
    """Context manager: times the block, appends the finished Span to the
    registry, and (by default) also feeds ``span/<name>/duration_s`` into a
    latency histogram so spans aggregate without post-processing."""
    reg = registry if registry is not None else M.get_registry()
    st = _stack()
    parent = st[-1].name if st else None
    sp = Span(name, time.perf_counter(), parent=parent, depth=len(st),
              attrs=dict(attrs))
    st.append(sp)
    try:
        yield sp
    finally:
        sp.end_s = time.perf_counter()
        st.pop()
        reg.spans.append(sp)
        if record_histogram:
            reg.observe(f"span/{name}/duration_s", sp.duration_s,
                        M.LATENCY_EDGES_S)
