"""Process-global metrics: counters, gauges, histograms.

The serving path is a mix of plain Python (scheduler, engine loop) and
jit-compiled JAX (the decode step, including the routed shared-attention
dispatch). Plain Python code records directly on the registry; traced code
must NOT — a direct record inside a jit'd function fires once at trace time
and never again. For traced values use ``jit_inc``/``jit_observe``/
``jit_gauge``, which lower to ``jax.debug.callback`` so the record happens
on every *execution*. Those helpers are gated by ``enable_jit_metrics``
(checked at trace time) so the default compiled programs carry no host
callbacks — dry-runs, HLO cost analysis, and multi-device lowering see the
exact same HLO as before this module existed.

This module deliberately has no jax import at module level: the scheduler
and exporters stay importable in dependency-free contexts.
"""
from __future__ import annotations

import bisect
import threading
from typing import Dict, List, Optional, Sequence, Tuple, Union

Number = Union[int, float]

# ---------------------------------------------------------------------------
# bucket-edge conventions (documented in README "Metrics & tracing")
# ---------------------------------------------------------------------------

#: wall-clock latencies in seconds: log-ish spaced 100us .. 10s
LATENCY_EDGES_S: Tuple[float, ...] = (
    1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0)

#: ratios in [0, 1] (occupancy, capacity utilization, batch density)
FRACTION_EDGES: Tuple[float, ...] = tuple(i / 10.0 for i in range(1, 11))

#: small integer counts (wave sizes, chunks, drops): powers of two
COUNT_EDGES: Tuple[float, ...] = (
    1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024)

#: transfer sizes in bytes (host-tier page offload / swap-in payloads):
#: powers of four from 1 KiB to 1 GiB
BYTES_EDGES: Tuple[float, ...] = tuple(float((4 ** i) * 1024)
                                       for i in range(11))

DEFAULT_EDGES = LATENCY_EDGES_S


class Counter:
    """Monotonic cumulative counter."""

    kind = "counter"
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, v: Number = 1) -> None:
        if v < 0:
            raise ValueError(f"counter {self.name}: negative increment {v}")
        self.value += float(v)

    def snapshot(self) -> dict:
        return {"kind": self.kind, "value": self.value}


class Gauge:
    """Last-write-wins instantaneous value (also tracks min/max seen)."""

    kind = "gauge"
    __slots__ = ("name", "value", "min", "max", "updates")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None
        self.updates = 0

    def set(self, v: Number) -> None:
        v = float(v)
        self.value = v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)
        self.updates += 1

    def snapshot(self) -> dict:
        return {"kind": self.kind, "value": self.value, "min": self.min,
                "max": self.max, "updates": self.updates}


class Histogram:
    """Fixed-bucket histogram.

    ``edges`` are upper bounds: bucket ``i`` counts observations
    ``v <= edges[i]`` (and ``> edges[i-1]``); one implicit overflow bucket
    counts ``v > edges[-1]``. Non-cumulative counts; ``counts`` has
    ``len(edges) + 1`` entries.
    """

    kind = "histogram"
    __slots__ = ("name", "edges", "counts", "count", "sum", "min", "max")

    def __init__(self, name: str, edges: Sequence[Number] = DEFAULT_EDGES):
        if not edges or list(edges) != sorted(set(float(e) for e in edges)):
            raise ValueError(
                f"histogram {name}: edges must be strictly increasing "
                f"and non-empty, got {edges!r}")
        self.name = name
        self.edges: Tuple[float, ...] = tuple(float(e) for e in edges)
        self.counts: List[int] = [0] * (len(self.edges) + 1)
        self.count = 0
        self.sum = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None

    def observe(self, v: Number) -> None:
        v = float(v)
        self.counts[bisect.bisect_left(self.edges, v)] += 1
        self.count += 1
        self.sum += v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """Approximate quantile: upper edge of the bucket holding rank q."""
        if not self.count:
            return 0.0
        rank = q * self.count
        acc = 0
        for i, c in enumerate(self.counts):
            acc += c
            if acc >= rank and c:
                return (self.edges[i] if i < len(self.edges)
                        else (self.max if self.max is not None else 0.0))
        return self.max if self.max is not None else 0.0

    def snapshot(self) -> dict:
        return {"kind": self.kind, "edges": list(self.edges),
                "counts": list(self.counts), "count": self.count,
                "sum": self.sum, "min": self.min, "max": self.max,
                "mean": self.mean}


Metric = Union[Counter, Gauge, Histogram]


class MetricsRegistry:
    """Named metrics + completed trace spans. Thread-safe get-or-create."""

    def __init__(self):
        self._lock = threading.RLock()
        self._metrics: Dict[str, Metric] = {}
        self.spans: List[object] = []     # trace.Span, appended by trace.py

    # -- get-or-create ---------------------------------------------------
    def _get(self, name: str, cls, *args) -> Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, *args)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(f"metric {name!r} is a {m.kind}, "
                                f"not a {cls.kind}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str,
                  edges: Sequence[Number] = DEFAULT_EDGES) -> Histogram:
        return self._get(name, Histogram, edges)

    # -- convenience -----------------------------------------------------
    def inc(self, name: str, v: Number = 1) -> None:
        self.counter(name).inc(v)

    def set_gauge(self, name: str, v: Number) -> None:
        self.gauge(name).set(v)

    def observe(self, name: str, v: Number,
                edges: Sequence[Number] = DEFAULT_EDGES) -> None:
        self.histogram(name, edges).observe(v)

    # -- introspection ---------------------------------------------------
    def get(self, name: str) -> Optional[Metric]:
        with self._lock:
            return self._metrics.get(name)

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._metrics)

    def snapshot(self) -> Dict[str, dict]:
        with self._lock:
            return {n: m.snapshot() for n, m in sorted(self._metrics.items())}

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()
            self.spans.clear()


# ---------------------------------------------------------------------------
# process-global registry
# ---------------------------------------------------------------------------

_global_lock = threading.Lock()
_global_registry = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    return _global_registry


def set_registry(reg: MetricsRegistry) -> MetricsRegistry:
    """Swap the process-global registry (tests / isolated benches).
    Returns the previous registry."""
    global _global_registry
    with _global_lock:
        prev, _global_registry = _global_registry, reg
        return prev


def reset_registry() -> None:
    _global_registry.reset()


# ---------------------------------------------------------------------------
# jit-safe recording (trace-time gated host callbacks)
# ---------------------------------------------------------------------------

#: checked at TRACE time — flip before building the jit'd serving step.
JIT_METRICS = False


def enable_jit_metrics(on: bool = True) -> None:
    """Enable metric callbacks inside jit-compiled code. Must be set before
    the function is traced; already-compiled programs are unaffected."""
    global JIT_METRICS
    JIT_METRICS = on


def _cb_inc(name, v):
    get_registry().inc(name, float(v))


def _cb_gauge(name, v):
    get_registry().set_gauge(name, float(v))


def _cb_observe(name, edges, v):
    get_registry().observe(name, float(v), edges)


def _cb_observe_per(prefix, edges, label, v):
    get_registry().observe(f"{prefix}/L{int(label)}", float(v), edges)


def _cb_inc_per(prefix, label, v):
    get_registry().inc(f"{prefix}/L{int(label)}", float(v))


def _callback(fn, *values) -> None:
    import jax
    jax.debug.callback(fn, *values)


def jit_inc(name: str, value) -> None:
    """Counter increment from (possibly) traced code; no-op unless
    ``enable_jit_metrics(True)`` was called before tracing."""
    if JIT_METRICS:
        import functools
        _callback(functools.partial(_cb_inc, name), value)


def jit_gauge(name: str, value) -> None:
    if JIT_METRICS:
        import functools
        _callback(functools.partial(_cb_gauge, name), value)


def jit_observe(name: str, value,
                edges: Sequence[Number] = DEFAULT_EDGES) -> None:
    if JIT_METRICS:
        import functools
        _callback(functools.partial(_cb_observe, name, tuple(edges)), value)


def jit_observe_per(prefix: str, label, value,
                    edges: Sequence[Number] = DEFAULT_EDGES) -> None:
    """Histogram observation under a runtime-labeled name
    (``{prefix}/L{label}``). Metric names are static strings, but inside a
    ``lax.scan`` over layers the layer index is a traced value — so the
    label rides to the host as a callback operand and the name is formed
    there. Used for the per-layer dispatch histograms."""
    if JIT_METRICS:
        import functools
        _callback(functools.partial(_cb_observe_per, prefix, tuple(edges)),
                  label, value)


def jit_inc_per(prefix: str, label, value) -> None:
    """Counter increment under a runtime-labeled name
    (``{prefix}/L{label}``) — the counter sibling of
    :func:`jit_observe_per`, for per-layer counts recorded inside the
    layer ``lax.scan`` (e.g. dropped queries by layer)."""
    if JIT_METRICS:
        import functools
        _callback(functools.partial(_cb_inc_per, prefix), label, value)
