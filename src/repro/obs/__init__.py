"""Serving-path observability: metrics registry, trace spans, exporters.

  metrics   process-global MetricsRegistry (counters/gauges/histograms)
            + jit-safe recording via jax.debug.callback
  trace     span() context manager with per-thread parent nesting
  export    JSON (round-trippable) and line-protocol dumps

Plain Python records directly (``get_registry().inc(...)``); jit-traced
code uses ``jit_inc``/``jit_gauge``/``jit_observe``, which are no-ops
unless ``enable_jit_metrics(True)`` was called before tracing.
"""
from repro.obs.export import (  # noqa: F401
    StreamingExporter, dump, from_dict, load, to_dict, to_json, to_lines,
)
from repro.obs.metrics import (  # noqa: F401
    BYTES_EDGES, COUNT_EDGES, FRACTION_EDGES, LATENCY_EDGES_S,
    Counter, Gauge, Histogram, MetricsRegistry,
    enable_jit_metrics, get_registry, jit_gauge, jit_inc, jit_inc_per,
    jit_observe, jit_observe_per, reset_registry, set_registry,
)
from repro.obs.trace import Span, current_span, span  # noqa: F401
