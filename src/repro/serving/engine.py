"""MoSKA serving engine: continuous batching over slot-based decode waves.

The full request path of the paper's system:

  register_corpus()  — precompute a domain corpus' KV once (prefill) and
                       chunk it into a SharedKVStore ("experts"), persistent
                       across requests — the Shared-KV node state.
  submit()/run()     — scheduler admits requests into B slots; unique
                       prefill writes per-slot caches (Unique-KV node
                       state); each decode wave runs one jit'd step where
                       every layer routes + batches shared attention across
                       all concurrent slots (the GEMM) and LSE-merges with
                       per-slot unique attention.

Static shapes: (B slots, max_seq) so decode steps hit one compiled program.
Slot raggedness is handled by per-slot lengths; inactive slots decode
garbage into slot-local buffers that are masked out of results.

Zero-copy hot path: the (L, B, S, KH, D) unique-KV batch cache is allocated
once, kept resident on device across ``run()`` calls, and **donated** into
the jit'd decode step and the per-slot admission write — XLA mutates the
cache buffer in place instead of copying it every wave
(``engine/decode_cache_bytes_copied`` reports 0 when donation is on).
Admission writes only the admitted slot (``kvcache.write_slot_prefix``),
not a full-cache merge. Prefill prompt lengths are rounded up to a small
bucket set so the prefill jit cache stays bounded
(``engine/prefill_compile_count``) instead of growing with every distinct
prompt length; pad positions are excluded from routing and logits so the
bucketed program computes exactly what the exact-length program would.
``run()`` may be called repeatedly on one engine; finished slots are
rewritten (and their tails zeroed) on re-admission.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import ModelConfig
from repro.core.scheduler import Request, Scheduler, SchedulerConfig
from repro.core.shared_kv import SharedKVStore, build_store
from repro.kvcache.cache import KVCache, write_slot_prefix
from repro.models.model import Model, build_model

#: smallest prefill bucket; "auto" buckets are powers of two from here up
#: to 128, then multiples of 128 (the MoSKA prefill route-block size) up
#: to max_seq.
MIN_PREFILL_BUCKET = 16


def resolve_prefill_buckets(spec: Union[str, Sequence[int], None],
                            max_seq: int) -> Optional[Tuple[int, ...]]:
    """Resolve an EngineConfig.prefill_buckets spec to a sorted tuple.

    ``"auto"`` — powers of two in [16, 128], then multiples of 128, capped
    at max_seq. ``None`` or an empty sequence — bucketing off (exact
    prompt lengths; one prefill program per distinct length). A sequence —
    used as-is (each bucket must be <= 128 or a multiple of 128 for the
    routed shared-attention prefill to block evenly).
    """
    if spec is None:
        return None
    if isinstance(spec, str):
        if spec != "auto":
            raise ValueError(f"unknown prefill_buckets spec {spec!r}")
        buckets = []
        b = MIN_PREFILL_BUCKET
        while b <= min(max_seq, 128):
            buckets.append(b)
            b *= 2
        b = 256
        while b <= max_seq:
            buckets.append(b)
            b += 128
        return tuple(buckets) if buckets else None
    buckets = tuple(sorted(set(int(b) for b in spec)))
    if not buckets:
        return None
    for b in buckets:
        if b < 1 or b > max_seq:
            raise ValueError(f"prefill bucket {b} outside [1, {max_seq}]")
        if b > 128 and b % 128:
            raise ValueError(
                f"prefill bucket {b} > 128 must be a multiple of 128 "
                "(MoSKA prefill route-block size)")
    return buckets


def bucket_for(buckets: Optional[Tuple[int, ...]], n: int) -> int:
    """Smallest bucket >= n; falls back to the exact length when bucketing
    is off or n exceeds the largest bucket."""
    if buckets:
        for b in buckets:
            if b >= n:
                return b
    return n


@dataclass
class EngineConfig:
    max_slots: int = 4
    max_seq: int = 512
    eos_id: int = -1           # -1: never stop early
    greedy: bool = True
    mem_budget_bytes: float = float("inf")
    kernel: Optional[str] = None    # None|'pallas' for shared attention
    cache_dtype: Any = jnp.bfloat16
    # record dispatch-density metrics from inside the jit'd decode step
    # (trace-time switch; adds host callbacks to the compiled program)
    jit_metrics: bool = True
    # donate the persistent batch cache into the jit'd decode step and the
    # per-slot admission write (zero-copy; off = functional copies)
    donate_cache: bool = True
    # "auto" | None (exact lengths) | explicit bucket sequence
    prefill_buckets: Union[str, Sequence[int], None] = "auto"


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, engine_cfg: EngineConfig):
        self.cfg = cfg
        self.ecfg = engine_cfg
        self.model = build_model(cfg)
        self.params = params
        self.stores: Dict[str, SharedKVStore] = {}
        self.scheduler = Scheduler(SchedulerConfig(
            max_slots=engine_cfg.max_slots,
            mem_budget_bytes=engine_cfg.mem_budget_bytes,
            unique_bytes_per_token=cfg.kv_bytes_per_token,
            max_seq=engine_cfg.max_seq))
        if engine_cfg.jit_metrics:
            obs.enable_jit_metrics(True)
        donate = engine_cfg.donate_cache
        self._decode = jax.jit(self._decode_impl,
                               static_argnames=("use_store",),
                               donate_argnums=(2,) if donate else ())
        self._prefill = jax.jit(self._prefill_impl,
                                static_argnames=("use_store",))
        self._write_slot = jax.jit(self._write_slot_impl,
                                   donate_argnums=(0,) if donate else ())
        self._buckets = resolve_prefill_buckets(engine_cfg.prefill_buckets,
                                                engine_cfg.max_seq)
        self._prefill_keys: set = set()
        self._cache = None          # persistent (L, B, S, KH, D) batch cache
        self.metrics = {"decode_steps": 0, "prefills": 0,
                        "tokens_generated": 0, "wall_s": 0.0}

    @property
    def registry(self) -> obs.MetricsRegistry:
        return obs.get_registry()

    @property
    def prefill_buckets(self) -> Optional[Tuple[int, ...]]:
        return self._buckets

    # ------------------------------------------------------------------
    def register_corpus(self, corpus_id: str, tokens: np.ndarray) -> int:
        """Precompute + chunk a shared corpus' KV. Returns #chunks."""
        C = self.cfg.moska.chunk_size
        n = (len(tokens) // C) * C
        if n == 0:
            raise ValueError("corpus shorter than one chunk")
        with obs.span("engine.register_corpus", corpus_id=corpus_id,
                      tokens=n):
            toks = jnp.asarray(tokens[:n], jnp.int32)[None]
            cache = self.model.init_cache(1, n, self.ecfg.cache_dtype)
            _, cache = self.model.prefill(self.params, toks, cache)
            store = build_store(jax.block_until_ready(cache.k)[:, 0],
                                cache.v[:, 0], C)
        self.stores[corpus_id] = store
        reg = self.registry
        reg.inc("engine/corpora_registered")
        reg.inc("engine/corpus_tokens_prefilled", n)
        reg.set_gauge(f"engine/corpus/{corpus_id}/chunks", store.num_chunks)
        return store.num_chunks

    # ------------------------------------------------------------------
    def submit(self, prompt: Sequence[int], max_new_tokens: int,
               corpus_id: Optional[str] = None) -> int:
        if corpus_id is not None and corpus_id not in self.stores:
            raise KeyError(f"corpus {corpus_id!r} not registered")
        return self.scheduler.submit(prompt, max_new_tokens, corpus_id)

    # ------------------------------------------------------------------
    def _decode_impl(self, params, tokens, cache, store, use_store: bool):
        logits, cache = self.model.decode_step(
            params, tokens, cache, store=store if use_store else None,
            kernel=self.ecfg.kernel)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, cache

    def _prefill_impl(self, params, tokens, true_len, start, store,
                      use_store: bool):
        """One request's (possibly bucket-padded) prefill into a fresh
        1-batch cache sized to the bucket. Returns (first token, cache)."""
        slot_cache = self.model.init_cache(1, tokens.shape[1],
                                           self.ecfg.cache_dtype)
        logits, slot_cache = self.model.prefill(
            params, tokens, slot_cache,
            store=store if use_store else None,
            start_pos=start, true_len=true_len)
        first = jnp.argmax(logits[0]).astype(jnp.int32)
        return first, slot_cache

    def _write_slot_impl(self, cache, slot_cache, slot, true_len):
        return write_slot_prefix(cache, slot_cache, slot, true_len)

    def _active_store(self) -> Optional[SharedKVStore]:
        cid = self.scheduler.resident_corpus
        return self.stores.get(cid) if cid is not None else None

    # ------------------------------------------------------------------
    def _ensure_cache(self):
        """The persistent batch cache: allocated once, reused across
        ``run()`` calls (and reallocated only if a failed donated step
        consumed it)."""
        cache = self._cache
        if cache is not None:
            leaves = jax.tree.leaves(cache)
            if any(getattr(l, "is_deleted", lambda: False)() for l in leaves):
                cache = None
        if cache is None:
            cache = self.model.init_cache(self.ecfg.max_slots,
                                          self.ecfg.max_seq,
                                          self.ecfg.cache_dtype)
        nbytes = sum(getattr(l, "nbytes", 0) for l in jax.tree.leaves(cache))
        self.registry.set_gauge(
            "engine/decode_cache_bytes_copied",
            0 if self.ecfg.donate_cache else nbytes)
        self.registry.set_gauge("engine/decode_cache_bytes", nbytes)
        return cache

    def run(self, max_waves: int = 10**9) -> List[Request]:
        """Drive to completion (or max_waves); returns finished requests.

        May be called repeatedly: the batch cache stays resident on device
        between calls. Raises RuntimeError on a livelocked configuration
        (queued work that can never be admitted under mem_budget_bytes).
        """
        B = self.ecfg.max_slots
        reg = self.registry
        t0 = time.perf_counter()
        tok0 = self.metrics["tokens_generated"]
        cache = self._ensure_cache()
        self._cache = None      # run() holds the only live reference
        slot_tokens = np.zeros((B,), np.int32)

        waves = 0
        try:
            with obs.span("engine.run"):
                while not self.scheduler.idle and waves < max_waves:
                    admitted = self.scheduler.schedule()
                    for req in admitted:
                        tp = time.perf_counter()
                        cache, first = self._prefill_slot(cache, req)
                        reg.observe("engine/prefill_latency_s",
                                    time.perf_counter() - tp,
                                    obs.LATENCY_EDGES_S)
                        slot_tokens[req.slot] = first
                        self.scheduler.record_token(req, int(first),
                                                    self.ecfg.eos_id)
                        self.metrics["tokens_generated"] += 1
                        reg.inc("engine/tokens_generated")
                    active = self.scheduler.active()
                    if not active:
                        if not admitted and not self.scheduler.idle:
                            # nothing running, nothing admissible, queue
                            # non-empty: no wave can ever make progress
                            # (counted under scheduler/admission_deferred_mem)
                            raise RuntimeError(
                                "serving livelock: "
                                f"{len(self.scheduler.queue)} queued "
                                "request(s) but none admissible — "
                                f"mem_budget_bytes="
                                f"{self.ecfg.mem_budget_bytes:.3g} is below "
                                "one slot's cost "
                                f"({self.scheduler._slot_cost():.3g} bytes "
                                "+ resident shared stores)")
                        waves += 1
                        continue
                    store = self._active_store()
                    use_store = store is not None and self.cfg.moska.enabled
                    # batch density: fraction of the static wave the decode
                    # step spends on live requests (the N of the GEMM)
                    reg.observe("engine/wave_batch_density",
                                len(active) / B, obs.FRACTION_EDGES)
                    reg.observe("engine/wave_active_slots", len(active),
                                obs.COUNT_EDGES)
                    td = time.perf_counter()
                    nxt, cache = self._decode(self.params,
                                              jnp.asarray(slot_tokens),
                                              cache, store, use_store)
                    nxt = np.asarray(nxt)  # device sync: latency includes it
                    reg.observe("engine/decode_step_latency_s",
                                time.perf_counter() - td,
                                obs.LATENCY_EDGES_S)
                    for req in list(active):
                        tok = int(nxt[req.slot])
                        slot_tokens[req.slot] = tok
                        self.scheduler.record_token(req, tok, self.ecfg.eos_id)
                        self.metrics["tokens_generated"] += 1
                        reg.inc("engine/tokens_generated")
                        reg.inc("engine/decoded_tokens")
                    self.metrics["decode_steps"] += 1
                    reg.inc("engine/decode_steps")
                    waves += 1
        finally:
            self._cache = cache
        wall = time.perf_counter() - t0
        self.metrics["wall_s"] += wall
        reg.set_gauge("engine/last_run_wall_s", wall)
        reg.set_gauge("engine/last_run_tokens_per_s",
                      (self.metrics["tokens_generated"] - tok0) / wall
                      if wall > 0 else 0.0)
        return self.scheduler.finished

    # ------------------------------------------------------------------
    def _prefill_slot(self, cache, req: Request):
        """Prefill one slot: bucket-padded jit'd prefill + in-place per-slot
        write into the (donated) batch cache."""
        store = self.stores.get(req.corpus_id)
        if not isinstance(cache, KVCache):
            # non-KVCache families (ssm/hybrid/encdec states): legacy
            # full-merge path, exact lengths
            return self._prefill_slot_fallback(cache, req, store)
        true_len = len(req.prompt)
        pad_len = bucket_for(self._buckets, true_len)
        padded = np.zeros((1, pad_len), np.int32)
        padded[0, :true_len] = req.prompt
        start = store.total_tokens if store is not None else 0
        use_store = store is not None and self.cfg.moska.enabled
        key = (pad_len, use_store,
               tuple(store.k.shape) if use_store else None)
        if key not in self._prefill_keys:
            self._prefill_keys.add(key)
            self.registry.set_gauge("engine/prefill_compile_count",
                                    len(self._prefill_keys))
        first, slot_cache = self._prefill(
            self.params, jnp.asarray(padded),
            jnp.asarray(true_len, jnp.int32), jnp.asarray(start, jnp.int32),
            store, use_store)
        cache = self._write_slot(cache, slot_cache,
                                 jnp.asarray(req.slot, jnp.int32),
                                 jnp.asarray(true_len, jnp.int32))
        self.metrics["prefills"] += 1
        self.registry.inc("engine/prefills")
        return cache, int(first)

    def _prefill_slot_fallback(self, cache, req: Request, store):
        toks = jnp.asarray(req.prompt, jnp.int32)[None]
        slot_cache = self.model.init_cache(1, self.ecfg.max_seq,
                                           self.ecfg.cache_dtype)
        start = store.total_tokens if store is not None else 0
        logits, slot_cache = self.model.prefill(
            self.params, toks, slot_cache, store=store, start_pos=start)
        self.metrics["prefills"] += 1
        self.registry.inc("engine/prefills")
        first = int(np.argmax(np.asarray(logits)[0]))
        cache = _merge_slot_cache(cache, slot_cache, req.slot)
        return cache, first


def _merge_slot_cache(cache, slot_cache, slot: int):
    """Copy a 1-batch cache pytree into batch slot ``slot`` (full-copy
    reference path; the KVCache hot path uses ``write_slot_prefix``)."""
    def merge(dst, src):
        if dst.ndim == 1:          # (B,) lengths / offsets
            return dst.at[slot].set(src[0])
        # layer-stacked arrays: (L, B, ...) vs (L, 1, ...)
        if dst.ndim >= 2 and src.shape[0] == dst.shape[0] and \
                src.shape[1] == 1:
            if src.shape[2] <= dst.shape[2]:
                return dst.at[:, slot, :src.shape[2]].set(src[:, 0])
        raise ValueError(f"unmergeable cache leaf {dst.shape} <- {src.shape}")

    return jax.tree.map(merge, cache, slot_cache)
