"""MoSKA serving engine: continuous batching over slot-based decode waves.

The full request path of the paper's system:

  register_corpus()  — precompute a domain corpus' KV once (prefill) and
                       chunk it into a SharedKVStore ("experts"), persistent
                       across requests — the Shared-KV node state.
  submit()/run()     — scheduler admits requests into B slots; unique
                       prefill writes per-slot caches (Unique-KV node
                       state); each decode wave runs one jit'd step where
                       every layer routes + batches shared attention across
                       all concurrent slots (the GEMM) and LSE-merges with
                       per-slot unique attention.

Static shapes: (B slots, max_seq) so decode steps hit one compiled program.
Slot raggedness is handled by per-slot lengths; inactive slots decode
garbage into slot-local buffers that are masked out of results.

Zero-copy hot path: the (L, B, S, KH, D) unique-KV batch cache is allocated
once, kept resident on device across ``run()`` calls, and **donated** into
the jit'd decode step and the per-slot admission write — XLA mutates the
cache buffer in place instead of copying it every wave
(``engine/decode_cache_bytes_copied`` reports 0 when donation is on).
Admission writes only the admitted slot (``kvcache.write_slot_prefix``),
not a full-cache merge. Prefill prompt lengths are rounded up to a small
bucket set so the prefill jit cache stays bounded
(``engine/prefill_compile_count``) instead of growing with every distinct
prompt length; pad positions are excluded from routing and logits so the
bucketed program computes exactly what the exact-length program would.
``run()`` may be called repeatedly on one engine; finished slots are
rewritten (and their tails zeroed) on re-admission.

Paged KV layout (``EngineConfig(kv_layout="paged")``): instead of the
per-slot ``max_seq`` slab, unique KV lives in a pool of ``block_size``-token
pages mapped through per-slot block tables (``repro.kvcache``). Admission
allocates only the prompt's blocks, decode appends pages on demand, and
identical prompts over one corpus share pages copy-on-write — so the same
``mem_budget_bytes`` admits more concurrent requests. Generations are
bit-identical to the slotted layout (the gather view tiles ``max_seq``
exactly and masked positions carry exactly-zero probability). Prompts
longer than ``max_seq`` are served via chunked prefill
(``prefill_chunk``-token pieces against a growing scratch context).

Host memory tier (``EngineConfig(host_pool_blocks=N)``): prefix entries
the device pool LRU-evicts are copied page-granularly to a host-side
pool instead of being dropped; a later hit on the same
(corpus-fingerprint, prompt) key swaps the pages back into free device
blocks bit-exactly, skipping the prefill entirely
(``kvcache/swap_in_hits`` vs ``engine/prefill_tokens``). Only when the
host tier has also evicted the entry does the engine fall back to the
deterministic rebuild-from-tokens path. The scheduler participates via
the offload admission path: under block-budget pressure cold resident
pages are offloaded to admit new work rather than deferring it.
"""
from __future__ import annotations

import collections
import hashlib
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import ModelConfig
from repro.core.scheduler import Request, Scheduler, SchedulerConfig
from repro.core.shared_kv import SharedKVStore, build_store
from repro.kvcache.block_table import (SlotTables, blocks_for,
                                       validate_block_size)
from repro.kvcache.cache import KVCache, write_slot_prefix
from repro.kvcache.paged import (BlockPool, HostBlockPool, PagedKVCache,
                                 PoolExhausted, copy_block, extract_blocks,
                                 grow_paged_kv_cache, insert_blocks,
                                 write_blocks)
from repro.kvcache.transfer import PrefetchEngine
from repro.models.model import Model, build_model

#: smallest prefill bucket; "auto" buckets are powers of two from here up
#: to 128, then multiples of 128 (the MoSKA prefill route-block size) up
#: to max_seq.
MIN_PREFILL_BUCKET = 16


def resolve_prefill_buckets(spec: Union[str, Sequence[int], None],
                            max_seq: int) -> Optional[Tuple[int, ...]]:
    """Resolve an EngineConfig.prefill_buckets spec to a sorted tuple.

    ``"auto"`` — powers of two in [16, 128], then multiples of 128, capped
    at max_seq. ``None`` or an empty sequence — bucketing off (exact
    prompt lengths; one prefill program per distinct length). A sequence —
    used as-is (each bucket must be <= 128 or a multiple of 128 for the
    routed shared-attention prefill to block evenly).
    """
    if spec is None:
        return None
    if isinstance(spec, str):
        if spec != "auto":
            raise ValueError(f"unknown prefill_buckets spec {spec!r}")
        buckets = []
        b = MIN_PREFILL_BUCKET
        while b <= min(max_seq, 128):
            buckets.append(b)
            b *= 2
        b = 256
        while b <= max_seq:
            buckets.append(b)
            b += 128
        return tuple(buckets) if buckets else None
    buckets = tuple(sorted(set(int(b) for b in spec)))
    if not buckets:
        return None
    for b in buckets:
        if b < 1 or b > max_seq:
            raise ValueError(f"prefill bucket {b} outside [1, {max_seq}]")
        if b > 128 and b % 128:
            raise ValueError(
                f"prefill bucket {b} > 128 must be a multiple of 128 "
                "(MoSKA prefill route-block size)")
    return buckets


def bucket_for(buckets: Optional[Tuple[int, ...]], n: int) -> int:
    """Smallest bucket >= n; falls back to the exact length when bucketing
    is off or n exceeds the largest bucket."""
    if buckets:
        for b in buckets:
            if b >= n:
                return b
    return n


@dataclass
class EngineConfig:
    max_slots: int = 4
    max_seq: int = 512
    eos_id: int = -1           # -1: never stop early
    greedy: bool = True
    mem_budget_bytes: float = float("inf")
    kernel: Optional[str] = None    # None|'pallas' for shared attention
    cache_dtype: Any = jnp.bfloat16
    # record dispatch-density metrics from inside the jit'd decode step
    # (trace-time switch; adds host callbacks to the compiled program)
    jit_metrics: bool = True
    # donate the persistent batch cache into the jit'd decode step and the
    # per-slot admission write (zero-copy; off = functional copies)
    donate_cache: bool = True
    # "auto" | None (exact lengths) | explicit bucket sequence
    prefill_buckets: Union[str, Sequence[int], None] = "auto"
    # -- paged KV layout ------------------------------------------------
    # "slotted": one (L, B, max_seq, KH, D) slab, every slot pays max_seq.
    # "paged": block-pool unique KV with per-slot block tables
    # (dense-family caches only); bit-identical generations, less HBM.
    kv_layout: str = "slotted"
    block_size: int = 16        # tokens per page; must divide max_seq
    # fixed pool size in blocks (incl. the reserved null block); None =
    # start small and grow on demand (hbm_high_water_bytes tracks demand)
    num_blocks: Optional[int] = None
    # chunk length for prompts past max_seq (multiple of 128 keeps the
    # shared-attention route blocks aligned with the single-shot prefill)
    prefill_chunk: int = 128
    # cache completed prompts' pages and remap them (copy-on-write) into
    # later requests with an identical (corpus-content, prompt) key —
    # keyed by corpus *fingerprint*, not id, so identical prompt prefixes
    # hit regardless of which registered store a request is bound to;
    # LRU-evicted under pool pressure
    share_prefix_blocks: bool = True
    # host memory tier (paged layout): capacity, in blocks, of the host
    # pool that LRU-evicted prefix pages are offloaded to instead of
    # being dropped; a later prefix hit swaps them back into free device
    # blocks bit-exactly. 0 disables the tier (evictions rebuild from
    # tokens on the next cold hit).
    host_pool_blocks: int = 0
    # -- async serving pipeline (paged layout) --------------------------
    # in-flight budget for prefetched host->device page copies: during
    # each decode wave, prefix entries the scheduler lookahead predicts
    # will be admitted next are device_put'd early, so the swap-in at
    # admission pays no transfer stall (kvcache/prefetch_{issued,hits,
    # wasted}). 0 disables prefetching (the PR 9 synchronous swap-in).
    prefetch_depth: int = 2
    # speculative decode appends: allocate the *next* page for any slot
    # whose next token lands on a fresh page boundary during the current
    # wave, keeping allocator/eviction work off the boundary wave's
    # critical path; unused pages are reclaimed on release
    # (kvcache/spec_pages_{alloc,reclaimed}).
    spec_append: bool = True
    # wave-overlap execution: dispatch the jit'd decode step, run the
    # next wave's host-side work (table tick, speculative appends,
    # prefetch issue) while the device computes, then block on results
    # (engine/overlap_saved_s vs engine/decode_stall_s). Off = block
    # immediately after dispatch, bit-identical generations.
    overlap_waves: bool = True


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, engine_cfg: EngineConfig):
        self.cfg = cfg
        self.ecfg = engine_cfg
        self.model = build_model(cfg)
        self.params = params
        self.stores: Dict[str, SharedKVStore] = {}
        self.scheduler = Scheduler(SchedulerConfig(
            max_slots=engine_cfg.max_slots,
            mem_budget_bytes=engine_cfg.mem_budget_bytes,
            unique_bytes_per_token=cfg.kv_bytes_per_token,
            max_seq=engine_cfg.max_seq,
            kv_layout=engine_cfg.kv_layout,
            block_size=engine_cfg.block_size))
        self.scheduler.set_store_evictor(self._on_store_evicted)
        if engine_cfg.jit_metrics:
            obs.enable_jit_metrics(True)
        donate = engine_cfg.donate_cache
        self._decode = jax.jit(self._decode_impl,
                               static_argnames=("use_store",),
                               donate_argnums=(2,) if donate else ())
        self._prefill = jax.jit(self._prefill_impl,
                                static_argnames=("use_store",))
        self._write_slot = jax.jit(self._write_slot_impl,
                                   donate_argnums=(0,) if donate else ())
        self._write_slot_pytree = jax.jit(
            self._write_slot_pytree_impl,
            donate_argnums=(0,) if donate else ())
        self._buckets = resolve_prefill_buckets(engine_cfg.prefill_buckets,
                                                engine_cfg.max_seq)
        self._prefill_keys: set = set()
        self._cache = None          # persistent (L, B, S, KH, D) batch cache
        # corpus token ids kept host-side so evicted stores can be rebuilt
        self._corpus_tokens: Dict[str, np.ndarray] = {}
        self._hbm_high_water = 0.0
        if engine_cfg.kv_layout == "paged":
            self._init_paged_state()
        elif engine_cfg.kv_layout != "slotted":
            raise ValueError(
                f"unknown kv_layout {engine_cfg.kv_layout!r} "
                "(expected 'slotted' or 'paged')")
        elif engine_cfg.host_pool_blocks:
            raise ValueError(
                "host_pool_blocks requires kv_layout='paged' (the host "
                "tier offloads pages, and the slotted layout has none)")
        self.metrics = {"decode_steps": 0, "prefills": 0,
                        "tokens_generated": 0, "wall_s": 0.0}
        # host-side callbacks run at the end of every decode wave (e.g.
        # the streaming metrics exporter's tick); must not touch device
        # state — the next wave may already be dispatched
        self.wave_hooks: List[Any] = []

    def _init_paged_state(self):
        ecfg = self.ecfg
        self.model._require_paged("kv_layout='paged'")
        validate_block_size(ecfg.block_size, ecfg.max_seq)
        if ecfg.prefill_chunk % ecfg.block_size:
            raise ValueError(
                f"prefill_chunk {ecfg.prefill_chunk} must be a multiple "
                f"of block_size {ecfg.block_size}")
        if ecfg.prefill_chunk > 128 and ecfg.prefill_chunk % 128:
            raise ValueError(
                f"prefill_chunk {ecfg.prefill_chunk} > 128 must be a "
                "multiple of 128 (shared-attention route-block size)")
        m0 = ecfg.max_seq // ecfg.block_size
        # pool growth quantum: one slotted slot's worth of pages, so the
        # decode program recompiles O(total/max_seq) times, not per request
        self._pool_quantum = m0
        cap = ecfg.num_blocks if ecfg.num_blocks is not None else 1 + m0
        self._block_pool = BlockPool(cap)
        self._tables = SlotTables(ecfg.max_slots, m0, ecfg.block_size)
        self._pool: Optional[PagedKVCache] = None   # device pages, lazy
        # (corpus fingerprint, prompt tuple) -> {"blocks": [...],
        # "first": tok}, LRU — fingerprint-keyed so identical prefixes
        # hit across stores with the same corpus content
        self._prefix_cache: "collections.OrderedDict" = \
            collections.OrderedDict()
        self._corpus_fp: Dict[str, str] = {}
        # host memory tier for LRU-evicted prefix pages (capacity 0 = off)
        self._host_pool = HostBlockPool(ecfg.host_pool_blocks)
        # async swap-in: prefetched host->device copies for predicted
        # admissions (only meaningful when the host tier can hold entries
        # and prefix sharing gives them a key to hit)
        self._prefetch: Optional[PrefetchEngine] = None
        if ecfg.host_pool_blocks and ecfg.prefetch_depth and \
                ecfg.share_prefix_blocks:
            self._prefetch = PrefetchEngine(self._host_pool,
                                            ecfg.prefetch_depth)
        # speculatively appended pages not yet written: slot -> table
        # index of the pre-allocated next page (reclaimed on release)
        self._spec_pending: Dict[int, int] = {}
        # the live device pool while run() executes, so the scheduler's
        # offload admission path can extract pages mid-schedule()
        self._cur_pool: Optional[PagedKVCache] = None
        self.scheduler.set_page_offloader(self._cold_page_bytes,
                                          self._offload_cold_pages)
        if ecfg.host_pool_blocks:
            self.registry.set_gauge("kvcache/host_pool_capacity_blocks",
                                    ecfg.host_pool_blocks)
        donate = ecfg.donate_cache
        self._decode_paged = jax.jit(self._decode_paged_impl,
                                     static_argnames=("use_store",),
                                     donate_argnums=(2,) if donate else ())
        self._prefill_chunked = jax.jit(self._prefill_chunk_impl,
                                        static_argnames=("use_store",))
        self._write_blocks = jax.jit(self._write_blocks_impl,
                                     donate_argnums=(0,) if donate else ())
        self._insert_blocks = jax.jit(insert_blocks,
                                      donate_argnums=(0,) if donate else ())

    @property
    def registry(self) -> obs.MetricsRegistry:
        return obs.get_registry()

    @property
    def prefill_buckets(self) -> Optional[Tuple[int, ...]]:
        return self._buckets

    # ------------------------------------------------------------------
    def register_corpus(self, corpus_id: str, tokens: np.ndarray) -> int:
        """Precompute + chunk a shared corpus' KV. Returns #chunks."""
        C = self.cfg.moska.chunk_size
        n = (len(tokens) // C) * C
        if n == 0:
            raise ValueError("corpus shorter than one chunk")
        toks = np.asarray(tokens[:n], np.int32)
        store = self._build_store(corpus_id, toks)
        self.stores[corpus_id] = store
        self._corpus_tokens[corpus_id] = toks
        self.scheduler.register_store(corpus_id, _pytree_nbytes(store))
        reg = self.registry
        reg.inc("engine/corpora_registered")
        reg.inc("engine/corpus_tokens_prefilled", n)
        reg.set_gauge(f"engine/corpus/{corpus_id}/chunks", store.num_chunks)
        return store.num_chunks

    def _build_store(self, corpus_id: str, toks: np.ndarray) -> SharedKVStore:
        C = self.cfg.moska.chunk_size
        with obs.span("engine.register_corpus", corpus_id=corpus_id,
                      tokens=len(toks)):
            cache = self.model.init_cache(1, len(toks), self.ecfg.cache_dtype)
            _, cache = self.model.prefill(self.params,
                                          jnp.asarray(toks)[None], cache)
            return build_store(jax.block_until_ready(cache.k)[:, 0],
                               cache.v[:, 0], C)

    def _on_store_evicted(self, corpus_id: str) -> None:
        """Scheduler LRU eviction callback: drop the store's device arrays
        (the host token ids are kept, so it can be rebuilt on demand)."""
        self.stores.pop(corpus_id, None)
        self.registry.inc("kvcache/stores_dropped")

    def _get_store(self, corpus_id: Optional[str]) -> Optional[SharedKVStore]:
        """The corpus' device store, rebuilding it if the scheduler evicted
        it for memory; touches its LRU clock."""
        if corpus_id is None:
            return None
        store = self.stores.get(corpus_id)
        if store is None:
            if corpus_id not in self._corpus_tokens:
                raise KeyError(f"corpus {corpus_id!r} not registered")
            store = self._build_store(corpus_id,
                                      self._corpus_tokens[corpus_id])
            self.stores[corpus_id] = store
            self.scheduler.mark_store_loaded(corpus_id)
            # rebalance: reloading may push colder stores out
            self.scheduler._evict_stores_for(0.0, keep=corpus_id)
            self.registry.inc("kvcache/store_reloads")
        self.scheduler.touch_store(corpus_id)
        return store

    # ------------------------------------------------------------------
    def submit(self, prompt: Sequence[int], max_new_tokens: int,
               corpus_id: Optional[str] = None) -> int:
        # registration outlives device residency: an LRU-evicted store is
        # rebuilt from its kept tokens when the corpus becomes resident
        if corpus_id is not None and corpus_id not in self._corpus_tokens \
                and corpus_id not in self.stores:
            raise KeyError(f"corpus {corpus_id!r} not registered")
        return self.scheduler.submit(prompt, max_new_tokens, corpus_id)

    # ------------------------------------------------------------------
    def _decode_impl(self, params, tokens, cache, store, use_store: bool):
        logits, cache = self.model.decode_step(
            params, tokens, cache, store=store if use_store else None,
            kernel=self.ecfg.kernel)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, cache

    def _prefill_impl(self, params, tokens, true_len, start, store,
                      use_store: bool):
        """One request's (possibly bucket-padded) prefill into a fresh
        1-batch cache sized to the bucket. Returns (first token, cache)."""
        slot_cache = self.model.init_cache(1, tokens.shape[1],
                                           self.ecfg.cache_dtype)
        logits, slot_cache = self.model.prefill(
            params, tokens, slot_cache,
            store=store if use_store else None,
            start_pos=start, true_len=true_len)
        first = jnp.argmax(logits[0]).astype(jnp.int32)
        return first, slot_cache

    def _write_slot_impl(self, cache, slot_cache, slot, true_len):
        return write_slot_prefix(cache, slot_cache, slot, true_len)

    def _write_slot_pytree_impl(self, cache, slot_cache, slot):
        """Slot-granular write for non-KVCache cache families (ssm/hybrid
        state pytrees): each (L, 1, S, ...) leaf lands at batch slot
        ``slot`` via dynamic_update_slice — donated, so the batch pytree is
        mutated in place instead of the legacy full-copy merge."""
        def merge(dst, src):
            if dst.ndim == 1:                    # (B,) lengths / offsets
                return dst.at[slot].set(src[0].astype(dst.dtype))
            start = (0, slot) + (0,) * (dst.ndim - 2)
            return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype),
                                                start)
        return jax.tree.map(merge, cache, slot_cache)

    def _decode_paged_impl(self, params, tokens, pool, table, lengths,
                           offsets, store, use_store: bool):
        logits, pool = self.model.decode_step_paged(
            params, tokens, pool, table, lengths, offsets,
            store=store if use_store else None, kernel=self.ecfg.kernel)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, pool

    def _prefill_chunk_impl(self, params, tokens, ctx, start, chunk_len,
                            store, use_store: bool):
        """One fixed-size chunk of a long prompt against the growing
        scratch context ``ctx``; returns (last-real-token argmax, ctx)."""
        logits, ctx = self.model.prefill_chunk(
            params, tokens, ctx, store=store if use_store else None,
            start_pos=start, chunk_len=chunk_len)
        first = jnp.argmax(logits[0]).astype(jnp.int32)
        return first, ctx

    def _write_blocks_impl(self, pool, block_ids, slot_k, slot_v, true_len):
        """Scatter a (possibly bucket-padded) 1-batch prefill cache into
        the pool pages ``block_ids``; pads/slices the prefix to exactly
        tile the blocks (positions >= true_len are zeroed either way)."""
        k, v = slot_k[:, 0], slot_v[:, 0]        # (L, S, KH, D)
        V = block_ids.shape[0] * pool.block_size
        S = k.shape[1]
        if S > V:
            k, v = k[:, :V], v[:, :V]
        elif S < V:
            pad = jnp.zeros((k.shape[0], V - S) + k.shape[2:], k.dtype)
            k = jnp.concatenate([k, pad], axis=1)
            v = jnp.concatenate([v, pad.astype(v.dtype)], axis=1)
        return write_blocks(pool, block_ids, k, v, true_len)

    def _active_store(self) -> Optional[SharedKVStore]:
        return self._get_store(self.scheduler.resident_corpus)

    # ------------------------------------------------------------------
    def _ensure_cache(self):
        """The persistent batch cache: allocated once, reused across
        ``run()`` calls (and reallocated only if a failed donated step
        consumed it)."""
        cache = self._cache
        if cache is not None:
            leaves = jax.tree.leaves(cache)
            if any(getattr(l, "is_deleted", lambda: False)() for l in leaves):
                cache = None
        if cache is None:
            cache = self.model.init_cache(self.ecfg.max_slots,
                                          self.ecfg.max_seq,
                                          self.ecfg.cache_dtype)
        nbytes = sum(getattr(l, "nbytes", 0) for l in jax.tree.leaves(cache))
        self.registry.set_gauge(
            "engine/decode_cache_bytes_copied",
            0 if self.ecfg.donate_cache else nbytes)
        self.registry.set_gauge("engine/decode_cache_bytes", nbytes)
        return cache

    def _note_hbm(self, kv_nbytes: float) -> None:
        """Track the peak of (unique KV + loaded shared stores) device
        bytes — the number the paged layout exists to shrink."""
        total = kv_nbytes + self.scheduler.shared_bytes
        if total > self._hbm_high_water:
            self._hbm_high_water = total
        self.registry.set_gauge("engine/hbm_high_water_bytes",
                                self._hbm_high_water)

    def run(self, max_waves: int = 10**9) -> List[Request]:
        """Drive to completion (or max_waves); returns finished requests.

        May be called repeatedly: the batch cache (slotted) / block pool
        (paged) stays resident on device between calls. Raises
        RuntimeError on a livelocked configuration (queued work that can
        never be admitted under mem_budget_bytes).
        """
        if self.ecfg.kv_layout == "paged":
            return self._run_paged(max_waves)
        B = self.ecfg.max_slots
        reg = self.registry
        t0 = time.perf_counter()
        tok0 = self.metrics["tokens_generated"]
        cache = self._ensure_cache()
        self._cache = None      # run() holds the only live reference
        cache_nbytes = _pytree_nbytes(cache)
        slot_tokens = np.zeros((B,), np.int32)

        waves = 0
        try:
            with obs.span("engine.run"):
                while not self.scheduler.idle and waves < max_waves:
                    admitted = self.scheduler.schedule()
                    for req in admitted:
                        tp = time.perf_counter()
                        cache, first = self._prefill_slot(cache, req)
                        reg.observe("engine/prefill_latency_s",
                                    time.perf_counter() - tp,
                                    obs.LATENCY_EDGES_S)
                        slot_tokens[req.slot] = first
                        self.scheduler.record_token(req, int(first),
                                                    self.ecfg.eos_id)
                        self.metrics["tokens_generated"] += 1
                        reg.inc("engine/tokens_generated")
                    active = self.scheduler.active()
                    if not active:
                        if not admitted and not self.scheduler.idle:
                            # nothing running, nothing admissible, queue
                            # non-empty: no wave can ever make progress
                            # (counted under scheduler/admission_deferred_mem)
                            raise RuntimeError(
                                "serving livelock: "
                                f"{len(self.scheduler.queue)} queued "
                                "request(s) but none admissible — "
                                f"mem_budget_bytes="
                                f"{self.ecfg.mem_budget_bytes:.3g} is below "
                                "one slot's cost "
                                f"({self.scheduler._slot_cost():.3g} bytes "
                                "+ resident shared stores)")
                        waves += 1
                        continue
                    store = self._active_store()
                    use_store = store is not None and self.cfg.moska.enabled
                    self._note_hbm(cache_nbytes)
                    # batch density: fraction of the static wave the decode
                    # step spends on live requests (the N of the GEMM)
                    reg.observe("engine/wave_batch_density",
                                len(active) / B, obs.FRACTION_EDGES)
                    reg.observe("engine/wave_active_slots", len(active),
                                obs.COUNT_EDGES)
                    td = time.perf_counter()
                    nxt, cache = self._decode(self.params,
                                              jnp.asarray(slot_tokens),
                                              cache, store, use_store)
                    nxt = np.asarray(nxt)  # device sync: latency includes it
                    reg.observe("engine/decode_step_latency_s",
                                time.perf_counter() - td,
                                obs.LATENCY_EDGES_S)
                    for req in list(active):
                        tok = int(nxt[req.slot])
                        slot_tokens[req.slot] = tok
                        self.scheduler.record_token(req, tok, self.ecfg.eos_id)
                        self.metrics["tokens_generated"] += 1
                        reg.inc("engine/tokens_generated")
                        reg.inc("engine/decoded_tokens")
                    self.metrics["decode_steps"] += 1
                    reg.inc("engine/decode_steps")
                    for hook in self.wave_hooks:
                        hook()
                    waves += 1
        finally:
            self._cache = cache
        wall = time.perf_counter() - t0
        self.metrics["wall_s"] += wall
        reg.set_gauge("engine/last_run_wall_s", wall)
        reg.set_gauge("engine/last_run_tokens_per_s",
                      (self.metrics["tokens_generated"] - tok0) / wall
                      if wall > 0 else 0.0)
        return self.scheduler.finished

    # -- paged KV layout ------------------------------------------------
    def _ensure_pool(self) -> PagedKVCache:
        """The persistent device block pool (paged analogue of
        ``_ensure_cache``)."""
        pool = self._pool
        if pool is not None:
            leaves = jax.tree.leaves(pool)
            if any(getattr(l, "is_deleted", lambda: False)() for l in leaves):
                pool = None
        if pool is None:
            pool = self.model.init_paged_cache(self._block_pool.num_blocks,
                                               self.ecfg.block_size,
                                               self.ecfg.cache_dtype)
        self.registry.set_gauge("engine/decode_cache_bytes", pool.nbytes)
        self.registry.set_gauge(
            "engine/decode_cache_bytes_copied",
            0 if self.ecfg.donate_cache else pool.nbytes)
        return pool

    def _corpus_fingerprint(self, corpus_id: Optional[str]) -> Optional[str]:
        """Content fingerprint of a registered corpus: requests bound to
        *different* store ids with identical corpus tokens share one
        prefix-cache namespace (their prefills are bit-identical — the
        unique KV depends only on corpus tokens + prompt, not the id)."""
        if corpus_id is None:
            return None
        fp = self._corpus_fp.get(corpus_id)
        if fp is None:
            toks = self._corpus_tokens[corpus_id]
            fp = hashlib.blake2b(np.ascontiguousarray(toks).tobytes(),
                                 digest_size=16).hexdigest()
            self._corpus_fp[corpus_id] = fp
        return fp

    def _prefix_key(self, req: Request):
        return (self._corpus_fingerprint(req.corpus_id), tuple(req.prompt))

    def _bytes_per_block(self) -> float:
        return self.cfg.kv_bytes_per_token * self.ecfg.block_size

    def _offload_entry(self, pool: PagedKVCache, key, entry) -> None:
        """Copy an evicted prefix entry's pages to the host tier — only
        when every page is cold (held solely by the prefix cache; pages a
        live slot still shares stay device-resident and re-park later)."""
        if not self.ecfg.host_pool_blocks:
            return
        bp = self._block_pool
        blocks = entry["blocks"]
        if any(bp.refcount(b) != 1 for b in blocks):
            return
        reg = self.registry
        t0 = time.perf_counter()
        k, v = extract_blocks(pool, blocks)
        gens = [(b, bp.generation(b)) for b in blocks]
        evicted = self._host_pool.offload(key, k, v, entry["first"], gens)
        reg.observe("kvcache/swap_out_latency_s",
                    time.perf_counter() - t0, obs.LATENCY_EDGES_S)
        nbytes = k.nbytes + v.nbytes
        reg.inc("kvcache/offload_bytes", nbytes)
        reg.observe("kvcache/swap_bytes", nbytes, obs.BYTES_EDGES)
        reg.inc("kvcache/offloads")
        if evicted:
            reg.inc("kvcache/host_pool_evictions", len(evicted))
        reg.set_gauge("kvcache/host_pool_blocks_used",
                      self._host_pool.used_blocks)

    def _evict_prefix_entries(self, pool: PagedKVCache,
                              need_blocks: int) -> Tuple[int, list]:
        """Evict LRU prefix-cache entries until ``need_blocks`` pages were
        actually released (or the cache is empty), offloading each cold
        entry's pages to the host tier first; returns (#released, evicted
        keys in eviction order)."""
        reg = self.registry
        released = 0
        evicted_keys = []
        while self._prefix_cache and released < need_blocks:
            key, entry = self._prefix_cache.popitem(last=False)
            self._offload_entry(pool, key, entry)
            released += self._block_pool.free(entry["blocks"])
            evicted_keys.append(key)
            reg.inc("kvcache/prefix_evictions")
        if released:
            reg.inc("kvcache/blocks_evicted", released)
        return released, evicted_keys

    def _cold_page_bytes(self) -> float:
        """Budget charge of pages held *only* by the prefix cache — what
        the scheduler's offload admission path can reclaim."""
        bp = self._block_pool
        cold = sum(1 for e in self._prefix_cache.values()
                   for b in e["blocks"] if bp.refcount(b) == 1)
        return cold * self._bytes_per_block()

    def _offload_cold_pages(self, need_bytes: float) -> float:
        """Scheduler callback (offload-vs-defer): move at least
        ``need_bytes`` of cold prefix pages to the host tier (or drop
        them when the tier is off) so a new request can be admitted.
        Returns the bytes actually freed."""
        pool = self._cur_pool
        if pool is None or not self._prefix_cache:
            return 0.0
        bpb = self._bytes_per_block()
        need_blocks = int(-(-need_bytes // bpb))
        released, _ = self._evict_prefix_entries(pool, need_blocks)
        return released * bpb

    def _alloc_blocks(self, pool: PagedKVCache, n: int,
                      reserve: int = 0) -> Tuple[PagedKVCache, List[int]]:
        """Allocate ``n`` pages, evicting cold prefix entries (offloading
        them to the host tier) and (in auto-sized mode) growing the device
        pool when the free list is short. ``reserve`` pages beyond ``n``
        size the growth so a request's decode appends don't retrigger it."""
        bp = self._block_pool
        want = n + reserve
        if bp.available < want:
            self._evict_prefix_entries(pool, want - bp.available)
        if bp.available < want and self.ecfg.num_blocks is None:
            q = self._pool_quantum
            shortfall = want - bp.available
            new_cap = bp.num_blocks + -(-shortfall // q) * q
            pool = grow_paged_kv_cache(pool, new_cap)
            bp.grow(new_cap)
            self.registry.inc("kvcache/pool_growths")
        return pool, bp.alloc(n)     # PoolExhausted if still short of n

    def _record_block_gauges(self) -> None:
        bp = self._block_pool
        reg = self.registry
        reg.set_gauge("kvcache/blocks_in_use", bp.in_use)
        reg.set_gauge("kvcache/blocks_free", bp.available)
        reg.set_gauge("kvcache/block_capacity", bp.capacity)
        reg.set_gauge("kvcache/block_utilization",
                      bp.in_use / max(bp.capacity, 1))

    def _prefill_slot_paged(self, pool: PagedKVCache, req: Request
                            ) -> Tuple[PagedKVCache, int]:
        """Admit one request into the paged pool: prefix-cache hit remaps
        shared pages; in-bucket prompts reuse the bucketed jit'd prefill
        (bit-identical to slotted) + a block scatter; prompts past max_seq
        go through chunked prefill."""
        reg = self.registry
        bs = self.ecfg.block_size
        true_len = len(req.prompt)
        total_blocks = blocks_for(true_len + req.max_new_tokens, bs)
        if total_blocks > self._tables.blocks_per_slot:
            self._tables.grow(total_blocks)   # wider gather view; recompile
        store = self._get_store(req.corpus_id)
        start = store.total_tokens if store is not None else 0
        use_store = store is not None and self.cfg.moska.enabled

        key = self._prefix_key(req)
        entry = (self._prefix_cache.get(key)
                 if self.ecfg.share_prefix_blocks else None)
        if entry is not None:
            self._prefix_cache.move_to_end(key)
            self._block_pool.incref(entry["blocks"])
            self._tables.assign(req.slot, entry["blocks"], true_len, start)
            reg.inc("kvcache/prefix_hits")
            reg.inc("kvcache/blocks_shared", len(entry["blocks"]))
            return pool, int(entry["first"])

        nb = blocks_for(true_len, bs)
        if self.ecfg.share_prefix_blocks and key in self._host_pool:
            # host-tier hit: swap the offloaded pages back into freshly
            # allocated device blocks — bit-exact, no prefill at all.
            # Fetch before alloc: the alloc may evict other prefix
            # entries into the host pool, which must not push this one out
            host_entry = self._host_pool.fetch(key)
            tr = (self._prefetch.take(key)
                  if self._prefetch is not None else None)
            pool, ids = self._alloc_blocks(pool, nb,
                                           reserve=total_blocks - nb)
            t0 = time.perf_counter()
            if tr is not None and tr["gens"] == host_entry["gens"]:
                # prefetched during an earlier wave: the pages are already
                # device-resident (or mid-flight — the insert sequences
                # after the async copy, a bounded wait, never a re-issue)
                src_k, src_v = tr["k"], tr["v"]
                reg.inc("kvcache/prefetch_hits")
            else:
                if tr is not None:
                    # the tier churned since issue: this transfer names a
                    # dead page lifetime — discard it and swap in the
                    # current entry (bit-identical values either way; the
                    # generation tags are the identity proof)
                    reg.inc("kvcache/prefetch_wasted")
                src_k, src_v = host_entry["k"], host_entry["v"]
            pool = self._insert_blocks(pool, jnp.asarray(ids, jnp.int32),
                                       src_k, src_v)
            reg.observe("kvcache/swap_in_latency_s",
                        time.perf_counter() - t0, obs.LATENCY_EDGES_S)
            nbytes = host_entry["k"].nbytes + host_entry["v"].nbytes
            reg.inc("kvcache/swap_in_bytes", nbytes)
            reg.observe("kvcache/swap_bytes", nbytes, obs.BYTES_EDGES)
            reg.inc("kvcache/swap_in_hits")
            reg.set_gauge("kvcache/host_pool_blocks_used",
                          self._host_pool.used_blocks)
            self._tables.assign(req.slot, ids, true_len, start)
            # the slot owns the swapped-in pages exactly as if it had
            # rebuilt them (same block pressure, no CoW on the tail);
            # they re-park in the prefix cache at release
            return pool, int(host_entry["first"])
        if self.ecfg.host_pool_blocks and self.ecfg.share_prefix_blocks:
            # cold miss in both tiers: deterministic rebuild-from-tokens
            reg.inc("kvcache/host_pool_misses")
        pool, ids = self._alloc_blocks(pool, nb, reserve=total_blocks - nb)
        if true_len <= self.ecfg.max_seq:
            pad_len = bucket_for(self._buckets, true_len)
            padded = np.zeros((1, pad_len), np.int32)
            padded[0, :true_len] = req.prompt
            pkey = (pad_len, use_store,
                    tuple(store.k.shape) if use_store else None)
            if pkey not in self._prefill_keys:
                self._prefill_keys.add(pkey)
                reg.set_gauge("engine/prefill_compile_count",
                              len(self._prefill_keys))
            first, slot_cache = self._prefill(
                self.params, jnp.asarray(padded),
                jnp.asarray(true_len, jnp.int32),
                jnp.asarray(start, jnp.int32), store, use_store)
        else:
            first, slot_cache = self._prefill_chunked_prompt(
                req, store, use_store, start)
        pool = self._write_blocks(pool, jnp.asarray(ids, jnp.int32),
                                  slot_cache.k, slot_cache.v,
                                  jnp.asarray(true_len, jnp.int32))
        self._tables.assign(req.slot, ids, true_len, start)
        self.metrics["prefills"] += 1
        reg.inc("engine/prefills")
        reg.inc("engine/prefill_tokens", true_len)
        return pool, int(first)

    def _prefill_chunked_prompt(self, req: Request, store, use_store: bool,
                                start: int):
        """Long-prompt prefill in ``prefill_chunk``-token pieces against a
        growing scratch context (one compiled program per (chunk, context)
        shape pair, bounded regardless of prompt length)."""
        C = self.ecfg.prefill_chunk
        true_len = len(req.prompt)
        v_tot = blocks_for(true_len, C) * C
        ctx = self.model.init_cache(1, v_tot, self.ecfg.cache_dtype)
        pkey = ("chunk", C, v_tot, use_store,
                tuple(store.k.shape) if use_store else None)
        if pkey not in self._prefill_keys:
            self._prefill_keys.add(pkey)
            self.registry.set_gauge("engine/prefill_compile_count",
                                    len(self._prefill_keys))
        first = None
        for s0 in range(0, true_len, C):
            clen = min(C, true_len - s0)
            chunk = np.zeros((1, C), np.int32)
            chunk[0, :clen] = req.prompt[s0:s0 + clen]
            first, ctx = self._prefill_chunked(
                self.params, jnp.asarray(chunk), ctx,
                jnp.asarray(start, jnp.int32), jnp.asarray(clen, jnp.int32),
                store, use_store)
            self.registry.inc("engine/prefill_chunks")
        self.registry.inc("engine/chunked_prefills")
        return first, ctx

    def _prepare_wave_blocks(self, pool: PagedKVCache,
                             active: List[Request]) -> PagedKVCache:
        """Pre-wave page maintenance: every active slot is about to append
        one token at its current length — make sure the target page exists
        and is exclusively owned (copy-on-write for prefix-shared pages)."""
        tables = self._tables
        bp = self._block_pool
        reg = self.registry
        for req in active:
            slot = req.slot
            bi = int(tables.length[slot]) // self.ecfg.block_size
            spec = self._spec_pending.get(slot)
            if spec is not None and bi >= spec:
                # the speculatively appended page is now the write target:
                # it is fresh (refcount 1, never shared) so neither the
                # append nor the CoW branch below applies — exactly the
                # state the synchronous append would have produced
                del self._spec_pending[slot]
                continue
            if bi >= int(tables.n_blocks[slot]):
                if bi >= tables.blocks_per_slot:
                    tables.grow(bi + 1)
                pool, ids = self._alloc_blocks(pool, 1)
                tables.append_block(slot, ids[0])
                reg.inc("kvcache/blocks_appended")
            else:
                blk = int(tables.table[slot, bi])
                if bp.needs_copy(blk):
                    pool, ids = self._alloc_blocks(pool, 1)
                    pool = copy_block(pool, ids[0], blk)
                    tables.replace_block(slot, bi, ids[0])
                    bp.free([blk])
                    reg.inc("kvcache/cow_copies")
        return pool

    def _speculative_appends(self, active: List[Request]) -> None:
        """Decode-boundary page pre-allocation: any slot whose *next* token
        will land on a fresh page gets that page appended now, during the
        current wave, so the next ``_prepare_wave_blocks`` finds it already
        in the table (host-metadata work only — BlockPool free-list +
        numpy table mutation; the device pool is untouched, which matters
        because it is donated into the still-in-flight decode step).

        Deliberately conservative: never evicts, never grows the pool,
        never raises — a full free list simply defers to the synchronous
        append path, bit-identically. A wrong speculation (the request
        finishes on the boundary token) is reclaimed in
        ``_release_slot_paged``."""
        if not self.ecfg.spec_append:
            return
        tables = self._tables
        bp = self._block_pool
        bs = self.ecfg.block_size
        reg = self.registry
        for req in active:
            slot = req.slot
            if slot in self._spec_pending:
                continue
            # lengths were just tick()'d: the slot's NEXT append lands at
            # tables.length[slot]; speculate only when that position opens
            # a page the table doesn't have yet
            bi = int(tables.length[slot]) // bs
            if bi < int(tables.n_blocks[slot]) or \
                    bi >= tables.blocks_per_slot or bp.available < 1:
                continue
            ids = bp.alloc(1)
            tables.append_block(slot, ids[0])
            self._spec_pending[slot] = bi
            reg.inc("kvcache/spec_pages_alloc")
            reg.inc("kvcache/blocks_appended")

    def _issue_prefetches(self) -> None:
        """Prefetch host-tier entries the scheduler lookahead predicts will
        be admitted next: issue non-blocking host->device copies now so the
        swap-in at admission finds device-resident pages. Also sweeps
        transfers whose host entry churned since issue (counted as wasted).
        Host-metadata + async-dispatch work only — safe in the overlap
        window."""
        pf = self._prefetch
        if pf is None:
            return
        reg = self.registry
        stale = pf.sweep()
        if stale:
            reg.inc("kvcache/prefetch_wasted", stale)
        for req in self.scheduler.lookahead(pf.depth):
            key = self._prefix_key(req)
            if key in self._prefix_cache:
                continue    # device-resident: admission remaps, no copy
            if pf.issue(key):
                reg.inc("kvcache/prefetch_issued")

    def _release_slot_paged(self, req: Request, slot: int) -> None:
        """Free a finished request's pages; with prefix sharing on, its
        prompt pages (incl. the partial tail — later writers CoW it) are
        parked in the LRU prefix cache keyed by (corpus, prompt)."""
        tables = self._tables
        if self._spec_pending.pop(slot, None) is not None:
            # wrong speculation: the request finished before writing its
            # pre-allocated boundary page; tables.clear below frees it
            # with the rest of the slot (it is never in prefix_blocks —
            # it sits beyond the written region)
            self.registry.inc("kvcache/spec_pages_reclaimed")
        key = self._prefix_key(req)
        if self.ecfg.share_prefix_blocks and req.generated and \
                key not in self._prefix_cache:
            pblocks = tables.prefix_blocks(slot, len(req.prompt))
            if pblocks:
                self._block_pool.incref(pblocks)
                self._prefix_cache[key] = {"blocks": pblocks,
                                           "first": req.generated[0]}
        self._block_pool.free(tables.clear(slot))
        self.registry.inc("kvcache/slots_released")

    def _run_paged(self, max_waves: int) -> List[Request]:
        B = self.ecfg.max_slots
        reg = self.registry
        t0 = time.perf_counter()
        tok0 = self.metrics["tokens_generated"]
        pool = self._ensure_pool()
        self._pool = None       # run() holds the only live reference
        slot_tokens = np.zeros((B,), np.int32)

        waves = 0
        try:
            with obs.span("engine.run"):
                while not self.scheduler.idle and waves < max_waves:
                    # the offload admission path may extract pages from
                    # the live pool during schedule() (read-only)
                    self._cur_pool = pool
                    admitted = self.scheduler.schedule()
                    for req in admitted:
                        tp = time.perf_counter()
                        slot = req.slot
                        pool, first = self._prefill_slot_paged(pool, req)
                        reg.observe("engine/prefill_latency_s",
                                    time.perf_counter() - tp,
                                    obs.LATENCY_EDGES_S)
                        slot_tokens[slot] = first
                        self.scheduler.record_token(req, int(first),
                                                    self.ecfg.eos_id)
                        if req.done:
                            self._release_slot_paged(req, slot)
                        self.metrics["tokens_generated"] += 1
                        reg.inc("engine/tokens_generated")
                    active = self.scheduler.active()
                    if not active:
                        if not admitted and not self.scheduler.idle:
                            head = self.scheduler.queue[0]
                            raise RuntimeError(
                                "serving livelock: "
                                f"{len(self.scheduler.queue)} queued "
                                "request(s) but none admissible — "
                                f"mem_budget_bytes="
                                f"{self.ecfg.mem_budget_bytes:.3g} is below "
                                "the head request's block cost "
                                f"({self.scheduler._request_cost(head):.3g} "
                                "bytes + resident shared stores)")
                        waves += 1
                        continue
                    store = self._active_store()
                    use_store = store is not None and self.cfg.moska.enabled
                    pool = self._prepare_wave_blocks(pool, active)
                    self._note_hbm(pool.nbytes)
                    reg.observe("engine/wave_batch_density",
                                len(active) / B, obs.FRACTION_EDGES)
                    reg.observe("engine/wave_active_slots", len(active),
                                obs.COUNT_EDGES)
                    tbl, lens, offs = self._tables.device_args()
                    td = time.perf_counter()
                    nxt, pool = self._decode_paged(
                        self.params, jnp.asarray(slot_tokens), pool,
                        jnp.asarray(tbl), jnp.asarray(lens),
                        jnp.asarray(offs), store, use_store)
                    # jax returns from _decode_paged as soon as the step is
                    # *dispatched*; np.asarray(nxt) is the block. The wave's
                    # host-side bookkeeping (table tick, speculative page
                    # appends, prefetch issue, gauge reads) is identical
                    # either way — overlap mode runs it inside the dispatch
                    # window so the block absorbs it, sync mode runs it
                    # after. None of it may touch the device pool: that
                    # buffer is donated into the in-flight step.
                    if self.ecfg.overlap_waves:
                        th = time.perf_counter()
                        self._tables.tick()
                        self._speculative_appends(active)
                        self._issue_prefetches()
                        self._record_block_gauges()
                        reg.observe("engine/overlap_saved_s",
                                    time.perf_counter() - th,
                                    obs.LATENCY_EDGES_S)
                        ts = time.perf_counter()
                        nxt = np.asarray(nxt)  # device sync (residual wait)
                        stall = time.perf_counter() - ts
                    else:
                        ts = time.perf_counter()
                        nxt = np.asarray(nxt)  # device sync (full wait)
                        stall = time.perf_counter() - ts
                        self._tables.tick()
                        self._speculative_appends(active)
                        self._issue_prefetches()
                        self._record_block_gauges()
                    reg.observe("engine/decode_step_latency_s",
                                time.perf_counter() - td,
                                obs.LATENCY_EDGES_S)
                    reg.observe("engine/decode_stall_s", stall,
                                obs.LATENCY_EDGES_S)
                    for req in list(active):
                        tok = int(nxt[req.slot])
                        slot = req.slot
                        slot_tokens[slot] = tok
                        self.scheduler.record_token(req, tok,
                                                    self.ecfg.eos_id)
                        if req.done:
                            self._release_slot_paged(req, slot)
                        self.metrics["tokens_generated"] += 1
                        reg.inc("engine/tokens_generated")
                        reg.inc("engine/decoded_tokens")
                    self.metrics["decode_steps"] += 1
                    reg.inc("engine/decode_steps")
                    for hook in self.wave_hooks:
                        hook()
                    waves += 1
        finally:
            self._pool = pool
            self._cur_pool = None
        self._record_block_gauges()
        wall = time.perf_counter() - t0
        self.metrics["wall_s"] += wall
        reg.set_gauge("engine/last_run_wall_s", wall)
        reg.set_gauge("engine/last_run_tokens_per_s",
                      (self.metrics["tokens_generated"] - tok0) / wall
                      if wall > 0 else 0.0)
        return self.scheduler.finished

    # ------------------------------------------------------------------
    def _prefill_slot(self, cache, req: Request):
        """Prefill one slot: bucket-padded jit'd prefill + in-place per-slot
        write into the (donated) batch cache."""
        store = self._get_store(req.corpus_id)
        if not isinstance(cache, KVCache):
            # non-KVCache families (ssm/hybrid/encdec states): legacy
            # full-merge path, exact lengths
            return self._prefill_slot_fallback(cache, req, store)
        true_len = len(req.prompt)
        pad_len = bucket_for(self._buckets, true_len)
        padded = np.zeros((1, pad_len), np.int32)
        padded[0, :true_len] = req.prompt
        start = store.total_tokens if store is not None else 0
        use_store = store is not None and self.cfg.moska.enabled
        key = (pad_len, use_store,
               tuple(store.k.shape) if use_store else None)
        if key not in self._prefill_keys:
            self._prefill_keys.add(key)
            self.registry.set_gauge("engine/prefill_compile_count",
                                    len(self._prefill_keys))
        first, slot_cache = self._prefill(
            self.params, jnp.asarray(padded),
            jnp.asarray(true_len, jnp.int32), jnp.asarray(start, jnp.int32),
            store, use_store)
        cache = self._write_slot(cache, slot_cache,
                                 jnp.asarray(req.slot, jnp.int32),
                                 jnp.asarray(true_len, jnp.int32))
        self.metrics["prefills"] += 1
        self.registry.inc("engine/prefills")
        self.registry.inc("engine/prefill_tokens", true_len)
        return cache, int(first)

    def _prefill_slot_fallback(self, cache, req: Request, store):
        toks = jnp.asarray(req.prompt, jnp.int32)[None]
        slot_cache = self.model.init_cache(1, self.ecfg.max_seq,
                                           self.ecfg.cache_dtype)
        start = store.total_tokens if store is not None else 0
        logits, slot_cache = self.model.prefill(
            self.params, toks, slot_cache, store=store, start_pos=start)
        self.metrics["prefills"] += 1
        self.registry.inc("engine/prefills")
        first = int(np.argmax(np.asarray(logits)[0]))
        cache = self._write_slot_pytree(cache, slot_cache,
                                        jnp.asarray(req.slot, jnp.int32))
        return cache, first


def _pytree_nbytes(tree) -> int:
    return sum(getattr(l, "nbytes", 0) for l in jax.tree.leaves(tree))


def _merge_slot_cache(cache, slot_cache, slot: int):
    """Copy a 1-batch cache pytree into batch slot ``slot`` (full-copy
    reference path; the jit'd hot paths use ``write_slot_prefix`` /
    ``_write_slot_pytree``; kept as the differential-test oracle)."""
    def merge(dst, src):
        if dst.ndim == 1:          # (B,) lengths / offsets
            return dst.at[slot].set(src[0])
        # layer-stacked arrays: (L, B, ...) vs (L, 1, ...)
        if dst.ndim >= 2 and src.shape[0] == dst.shape[0] and \
                src.shape[1] == 1:
            if src.shape[2] <= dst.shape[2]:
                return dst.at[:, slot, :src.shape[2]].set(src[:, 0])
        raise ValueError(f"unmergeable cache leaf {dst.shape} <- {src.shape}")

    return jax.tree.map(merge, cache, slot_cache)
