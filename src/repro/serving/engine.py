"""MoSKA serving engine: continuous batching over slot-based decode waves.

The full request path of the paper's system:

  register_corpus()  — precompute a domain corpus' KV once (prefill) and
                       chunk it into a SharedKVStore ("experts"), persistent
                       across requests — the Shared-KV node state.
  submit()/run()     — scheduler admits requests into B slots; unique
                       prefill writes per-slot caches (Unique-KV node
                       state); each decode wave runs one jit'd step where
                       every layer routes + batches shared attention across
                       all concurrent slots (the GEMM) and LSE-merges with
                       per-slot unique attention.

Static shapes: (B slots, max_seq) so decode steps hit one compiled program.
Slot raggedness is handled by per-slot lengths; inactive slots decode
garbage into slot-local buffers that are reset on admission (masked out of
results).
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import ModelConfig
from repro.core.scheduler import Request, Scheduler, SchedulerConfig
from repro.core.shared_kv import SharedKVStore, build_store
from repro.models.model import Model, build_model


@dataclass
class EngineConfig:
    max_slots: int = 4
    max_seq: int = 512
    eos_id: int = -1           # -1: never stop early
    greedy: bool = True
    mem_budget_bytes: float = float("inf")
    kernel: Optional[str] = None    # None|'pallas' for shared attention
    cache_dtype: Any = jnp.bfloat16
    # record dispatch-density metrics from inside the jit'd decode step
    # (trace-time switch; adds host callbacks to the compiled program)
    jit_metrics: bool = True


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, engine_cfg: EngineConfig):
        self.cfg = cfg
        self.ecfg = engine_cfg
        self.model = build_model(cfg)
        self.params = params
        self.stores: Dict[str, SharedKVStore] = {}
        self.scheduler = Scheduler(SchedulerConfig(
            max_slots=engine_cfg.max_slots,
            mem_budget_bytes=engine_cfg.mem_budget_bytes,
            unique_bytes_per_token=cfg.kv_bytes_per_token,
            max_seq=engine_cfg.max_seq))
        if engine_cfg.jit_metrics:
            obs.enable_jit_metrics(True)
        self._decode = jax.jit(self._decode_impl, static_argnames=("use_store",))
        self.metrics = {"decode_steps": 0, "prefills": 0,
                        "tokens_generated": 0, "wall_s": 0.0}

    @property
    def registry(self) -> obs.MetricsRegistry:
        return obs.get_registry()

    # ------------------------------------------------------------------
    def register_corpus(self, corpus_id: str, tokens: np.ndarray) -> int:
        """Precompute + chunk a shared corpus' KV. Returns #chunks."""
        C = self.cfg.moska.chunk_size
        n = (len(tokens) // C) * C
        if n == 0:
            raise ValueError("corpus shorter than one chunk")
        with obs.span("engine.register_corpus", corpus_id=corpus_id,
                      tokens=n):
            toks = jnp.asarray(tokens[:n], jnp.int32)[None]
            cache = self.model.init_cache(1, n, self.ecfg.cache_dtype)
            _, cache = self.model.prefill(self.params, toks, cache)
            store = build_store(jax.block_until_ready(cache.k)[:, 0],
                                cache.v[:, 0], C)
        self.stores[corpus_id] = store
        reg = self.registry
        reg.inc("engine/corpora_registered")
        reg.inc("engine/corpus_tokens_prefilled", n)
        reg.set_gauge(f"engine/corpus/{corpus_id}/chunks", store.num_chunks)
        return store.num_chunks

    # ------------------------------------------------------------------
    def submit(self, prompt: Sequence[int], max_new_tokens: int,
               corpus_id: Optional[str] = None) -> int:
        if corpus_id is not None and corpus_id not in self.stores:
            raise KeyError(f"corpus {corpus_id!r} not registered")
        return self.scheduler.submit(prompt, max_new_tokens, corpus_id)

    # ------------------------------------------------------------------
    def _decode_impl(self, params, tokens, cache, store, use_store: bool):
        logits, cache = self.model.decode_step(
            params, tokens, cache, store=store if use_store else None,
            kernel=self.ecfg.kernel)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, cache

    def _active_store(self) -> Optional[SharedKVStore]:
        cid = self.scheduler.resident_corpus
        return self.stores.get(cid) if cid is not None else None

    def run(self, max_waves: int = 10**9) -> List[Request]:
        """Drive to completion (or max_waves); returns finished requests."""
        B = self.ecfg.max_slots
        S = self.ecfg.max_seq
        reg = self.registry
        t0 = time.perf_counter()
        tok0 = self.metrics["tokens_generated"]
        cache = self.model.init_cache(B, S, self.ecfg.cache_dtype)
        slot_tokens = np.zeros((B,), np.int32)

        waves = 0
        with obs.span("engine.run"):
            while not self.scheduler.idle and waves < max_waves:
                admitted = self.scheduler.schedule()
                for req in admitted:
                    tp = time.perf_counter()
                    cache, first = self._prefill_slot(cache, req)
                    reg.observe("engine/prefill_latency_s",
                                time.perf_counter() - tp,
                                obs.LATENCY_EDGES_S)
                    slot_tokens[req.slot] = first
                    self.scheduler.record_token(req, int(first),
                                                self.ecfg.eos_id)
                    self.metrics["tokens_generated"] += 1
                    reg.inc("engine/tokens_generated")
                active = self.scheduler.active()
                if not active:
                    waves += 1
                    continue
                store = self._active_store()
                use_store = store is not None and self.cfg.moska.enabled
                # batch density: fraction of the static wave the decode
                # step spends on live requests (the N of the GEMM batching)
                reg.observe("engine/wave_batch_density", len(active) / B,
                            obs.FRACTION_EDGES)
                reg.observe("engine/wave_active_slots", len(active),
                            obs.COUNT_EDGES)
                td = time.perf_counter()
                nxt, cache = self._decode(self.params,
                                          jnp.asarray(slot_tokens), cache,
                                          store, use_store)
                nxt = np.asarray(nxt)   # device sync: latency includes it
                reg.observe("engine/decode_step_latency_s",
                            time.perf_counter() - td, obs.LATENCY_EDGES_S)
                for req in list(active):
                    tok = int(nxt[req.slot])
                    slot_tokens[req.slot] = tok
                    self.scheduler.record_token(req, tok, self.ecfg.eos_id)
                    self.metrics["tokens_generated"] += 1
                    reg.inc("engine/tokens_generated")
                    reg.inc("engine/decoded_tokens")
                self.metrics["decode_steps"] += 1
                reg.inc("engine/decode_steps")
                waves += 1
        wall = time.perf_counter() - t0
        self.metrics["wall_s"] += wall
        reg.set_gauge("engine/last_run_wall_s", wall)
        reg.set_gauge("engine/last_run_tokens_per_s",
                      (self.metrics["tokens_generated"] - tok0) / wall
                      if wall > 0 else 0.0)
        return self.scheduler.finished

    # ------------------------------------------------------------------
    def _prefill_slot(self, cache, req: Request):
        """Prefill one slot; single-request prefill merged into the batch
        cache (per-slot write)."""
        toks = jnp.asarray(req.prompt, jnp.int32)[None]
        slot_cache = self.model.init_cache(1, self.ecfg.max_seq,
                                           self.ecfg.cache_dtype)
        store = self.stores.get(req.corpus_id)
        start = store.total_tokens if store is not None else 0
        logits, slot_cache = self.model.prefill(
            self.params, toks, slot_cache, store=store, start_pos=start)
        self.metrics["prefills"] += 1
        self.registry.inc("engine/prefills")
        first = int(np.argmax(np.asarray(logits)[0]))
        cache = _merge_slot_cache(cache, slot_cache, req.slot)
        return cache, first


def _merge_slot_cache(cache, slot_cache, slot: int):
    """Copy a 1-batch cache pytree into batch slot ``slot``."""
    def merge(dst, src):
        if dst.ndim == 1:          # (B,) lengths / offsets
            return dst.at[slot].set(src[0])
        # layer-stacked arrays: (L, B, ...) vs (L, 1, ...)
        if dst.ndim >= 2 and src.shape[0] == dst.shape[0] and \
                src.shape[1] == 1:
            if src.shape[2] <= dst.shape[2]:
                return dst.at[:, slot, :src.shape[2]].set(src[:, 0])
        raise ValueError(f"unmergeable cache leaf {dst.shape} <- {src.shape}")

    return jax.tree.map(merge, cache, slot_cache)
