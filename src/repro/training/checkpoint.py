"""Checkpointing: pytree <-> .npz with path-keyed flattening (no orbax).

Saves params / optimizer state / step under a directory with atomic
rename; restore reconstructs the exact pytree structure from a template.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":   # npz has no bf16: store as f32
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def save_checkpoint(ckpt_dir: str, step: int, params, opt_state=None,
                    extra: Optional[Dict[str, Any]] = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir)
    np.savez(os.path.join(tmp, "params.npz"), **_flatten(params))
    if opt_state is not None:
        np.savez(os.path.join(tmp, "opt.npz"), **_flatten(opt_state))
    meta = {"step": int(step), **(extra or {})}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(path):
        raise FileExistsError(path)
    os.rename(tmp, path)
    # refresh "latest" pointer
    with open(os.path.join(ckpt_dir, "LATEST"), "w") as f:
        f.write(os.path.basename(path))
    return path


def latest_checkpoint(ckpt_dir: str) -> Optional[str]:
    ptr = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        name = f.read().strip()
    path = os.path.join(ckpt_dir, name)
    return path if os.path.exists(path) else None


def _unflatten(template, flat: Dict[str, np.ndarray]):
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = flat[key]
        if hasattr(leaf, "dtype") and arr.dtype != leaf.dtype:
            import ml_dtypes  # noqa: F401 (registers bf16 casts)
            arr = arr.astype(leaf.dtype)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def restore_checkpoint(path: str, params_template,
                       opt_template=None) -> Tuple[int, Any, Any]:
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    pf = np.load(os.path.join(path, "params.npz"))
    params = _unflatten(params_template, dict(pf))
    opt = None
    opt_path = os.path.join(path, "opt.npz")
    if opt_template is not None and os.path.exists(opt_path):
        of = np.load(opt_path)
        opt = _unflatten(opt_template, dict(of))
    return meta["step"], params, opt
