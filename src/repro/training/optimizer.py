"""AdamW + cosine schedule, pytree-native (no optax dependency).

Optimizer moments are fp32 regardless of param dtype (mixed-precision
training discipline); the update is sharding-transparent — state inherits
the param PartitionSpecs so FSDP shards moments too.
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def adamw_init(params) -> AdamWState:
    f32 = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return AdamWState(jnp.zeros((), jnp.int32),
                      jax.tree.map(f32, params), jax.tree.map(f32, params))


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * base_lr * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return lr


def adamw_update(grads, state: AdamWState, params, *, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1,
                 grad_clip: float = 1.0) -> Tuple[Any, AdamWState]:
    step = state.step + 1
    lr_t = lr(step) if callable(lr) else lr

    # global-norm clip
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in leaves))
    scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))

    def upd(g, m, n, p):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1 - b1) * g
        n_new = b2 * n + (1 - b2) * g * g
        mhat = m_new / (1 - b1 ** step.astype(jnp.float32))
        nhat = n_new / (1 - b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(nhat) + eps) + weight_decay * \
            p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype), \
            m_new, n_new

    out = jax.tree.map(upd, grads, state.mu, state.nu, params)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step, new_mu, new_nu)
