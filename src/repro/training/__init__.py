from repro.training.optimizer import (  # noqa: F401
    AdamWState, adamw_init, adamw_update, cosine_schedule,
)
from repro.training.train_loop import TrainLoopConfig, train  # noqa: F401
