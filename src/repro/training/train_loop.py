"""Training loop: jit'd step (loss -> grads -> AdamW), data pipeline,
periodic checkpointing, metric log. Distribution comes from the caller:
under a mesh + rules the same step function runs FSDP+TP (launch/train.py);
without, it runs single-device (examples, smoke tests).
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Iterator, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.data.pipeline import make_train_batches
from repro.models.model import Model, build_model
from repro.training import checkpoint as ckpt_lib
from repro.training.optimizer import (adamw_init, adamw_update,
                                      cosine_schedule)


@dataclass
class TrainLoopConfig:
    num_steps: int = 100
    batch_size: int = 8
    seq_len: int = 256
    lr: float = 3e-4
    warmup: int = 10
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 0
    log_every: int = 10
    remat: bool = True
    seed: int = 0


def make_train_step(model: Model, loop_cfg: TrainLoopConfig
                    ) -> Callable:
    lr = cosine_schedule(loop_cfg.lr, loop_cfg.warmup, loop_cfg.num_steps)

    def step(params, opt_state, batch):
        def loss_fn(p):
            loss, metrics = model.train_loss(p, batch,
                                             remat=loop_cfg.remat)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        params, opt_state = adamw_update(
            grads, opt_state, params, lr=lr,
            weight_decay=loop_cfg.weight_decay,
            grad_clip=loop_cfg.grad_clip)
        metrics = dict(metrics, loss=loss)
        return params, opt_state, metrics

    return step


def train(cfg: ModelConfig, loop_cfg: TrainLoopConfig,
          batches: Optional[Iterator[Dict[str, Any]]] = None,
          params=None) -> Dict[str, Any]:
    model = build_model(cfg)
    key = jax.random.PRNGKey(loop_cfg.seed)
    if params is None:
        params = model.init(key)
    opt_state = adamw_init(params)

    start_step = 0
    if loop_cfg.ckpt_dir:
        latest = ckpt_lib.latest_checkpoint(loop_cfg.ckpt_dir)
        if latest:
            start_step, params, opt_state = ckpt_lib.restore_checkpoint(
                latest, params, opt_state)

    step_fn = jax.jit(make_train_step(model, loop_cfg), donate_argnums=(0, 1))

    if batches is None:
        batches = make_train_batches(cfg, loop_cfg.batch_size,
                                     loop_cfg.seq_len, seed=loop_cfg.seed)
    history: List[Dict[str, float]] = []
    t0 = time.perf_counter()
    for step_idx in range(start_step, loop_cfg.num_steps):
        batch = {k: jnp.asarray(v) for k, v in next(batches).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if (loop_cfg.log_every and step_idx % loop_cfg.log_every == 0) or \
                step_idx == loop_cfg.num_steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step_idx
            m["elapsed_s"] = time.perf_counter() - t0
            history.append(m)
            print(f"step {step_idx:5d} loss {m['loss']:.4f} "
                  f"ce {m.get('ce_loss', 0.0):.4f} "
                  f"({m['elapsed_s']:.1f}s)", flush=True)
        if (loop_cfg.ckpt_dir and loop_cfg.ckpt_every
                and (step_idx + 1) % loop_cfg.ckpt_every == 0):
            ckpt_lib.save_checkpoint(loop_cfg.ckpt_dir, step_idx + 1,
                                     params, opt_state)
    return {"params": params, "opt_state": opt_state, "history": history}
