"""Paged unique-KV cache: a block pool + ref-counted allocator.

Instead of one ``(L, B, max_seq, KH, D)`` slab where every slot pays for
the worst-case prompt, the paged layout keeps a pool of fixed-size pages

    k_pool, v_pool : (L, num_blocks, block_size, KH, D)

and maps each request's tokens onto pages through a block table
(``repro.kvcache.block_table``). Pages are recycled through a free list;
ref-counting lets several requests map the *same* physical page
(prefix sharing) with copy-on-write when one of them needs to append into
a shared page. This is the PagedAttention allocation model, fitted to the
MoSKA engine: short requests stop paying ``max_seq`` HBM, and identical
prompts over the same shared corpus are deduplicated into one set of
pages.

Split of responsibilities:
  * :class:`BlockPool` — host-side allocator (ids only, no device data):
    free list, refcounts, alloc/incref/free, CoW arbitration. Pure Python
    so the scheduler/engine can run it without touching the device, and
    so hypothesis can hammer its invariants. Every allocation stamps the
    block with a fresh *generation*, so a page's identity is the pair
    ``(block_id, generation)`` — a copy taken before the block was
    recycled can never be confused with the block's current contents.
  * :class:`HostBlockPool` — the host memory tier: LRU-bounded store of
    page *copies* (``jax.device_put`` to CPU) for prefix entries evicted
    from the device pool. Swap-in rehydrates them into freshly allocated
    device blocks bit-exactly; only when the host tier has also evicted
    an entry does the engine fall back to rebuild-from-tokens.
  * :class:`PagedKVCache` + the jit-friendly array ops below — the device
    data path: block-granular writes at admission, per-token scatter
    appends at decode, table gathers that rebuild a contiguous view for
    the attention (bit-identical to the slotted path when the view tiles
    ``max_seq`` exactly), page extraction/insertion for the host tier.
"""
from __future__ import annotations

import collections
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.kvcache.block_table import NULL_BLOCK


class PoolExhausted(RuntimeError):
    """No free block available (after any possible eviction)."""


class BlockPool:
    """Ref-counted free-list allocator over ``num_blocks`` physical pages.

    Block ``NULL_BLOCK`` (= 0) is reserved at construction: it is never
    handed out, it absorbs the decode wave's garbage-lane writes.

    Invariants (property-tested in tests/test_paged_kvcache.py):
      * a block is either free or has refcount >= 1, never both;
      * ``len(free) + len(live) == num_blocks - 1`` at all times;
      * refcounts never go negative; freeing to refcount 0 returns the
        block to the free list exactly once.
    """

    def __init__(self, num_blocks: int):
        if num_blocks < 2:
            raise ValueError(
                f"need >= 2 blocks (one is the reserved null block), "
                f"got {num_blocks}")
        self.num_blocks = num_blocks
        # LIFO free list: recently freed pages are re-used first (their
        # contents are garbage either way; LIFO keeps the working set hot)
        self._free: List[int] = list(range(num_blocks - 1, NULL_BLOCK, -1))
        self._ref = {}  # block id -> refcount >= 1
        # block id -> allocation generation (bumped on every alloc); a
        # page's identity is (block_id, generation), so host-tier copies
        # taken before a block was recycled are provably not aliases of
        # the block's current contents
        self._gen: Dict[int, int] = {}

    # -- introspection ---------------------------------------------------
    @property
    def capacity(self) -> int:
        """Allocatable blocks (the null block is not allocatable)."""
        return self.num_blocks - 1

    @property
    def available(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return len(self._ref)

    def refcount(self, block_id: int) -> int:
        return self._ref.get(block_id, 0)

    def is_free(self, block_id: int) -> bool:
        return block_id not in self._ref and block_id != NULL_BLOCK

    def generation(self, block_id: int) -> int:
        """Allocation generation of ``block_id`` (0 = never allocated).
        Strictly increases each time the block is handed out, so
        ``(block_id, generation)`` uniquely names one lifetime of a page."""
        return self._gen.get(block_id, 0)

    # -- allocation ------------------------------------------------------
    def alloc(self, n: int = 1) -> List[int]:
        """Allocate ``n`` blocks with refcount 1; raises PoolExhausted
        (allocating nothing) when fewer than ``n`` are free."""
        if n < 0:
            raise ValueError(f"negative allocation {n}")
        if n > len(self._free):
            raise PoolExhausted(
                f"need {n} blocks, {len(self._free)} free "
                f"(pool of {self.capacity})")
        ids = [self._free.pop() for _ in range(n)]
        for b in ids:
            self._ref[b] = 1
            self._gen[b] = self._gen.get(b, 0) + 1
        return ids

    def incref(self, block_ids: Sequence[int]) -> None:
        """Map already-live blocks into another table (prefix sharing)."""
        for b in block_ids:
            if b == NULL_BLOCK:
                continue
            if b not in self._ref:
                raise ValueError(f"incref of free block {b}")
            self._ref[b] += 1

    def free(self, block_ids: Sequence[int]) -> int:
        """Drop one reference per id; returns how many blocks actually
        returned to the free list (refcount hit 0)."""
        released = 0
        for b in block_ids:
            if b == NULL_BLOCK:
                continue
            c = self._ref.get(b)
            if c is None:
                raise ValueError(f"double free of block {b}")
            if c == 1:
                del self._ref[b]
                self._free.append(b)
                released += 1
            else:
                self._ref[b] = c - 1
        return released

    def needs_copy(self, block_id: int) -> bool:
        """True when writing into ``block_id`` requires copy-on-write
        (the page is mapped by more than one table)."""
        return self._ref.get(block_id, 0) > 1

    def grow(self, num_blocks: int) -> None:
        """Extend the pool (matches a device-side pool reallocation)."""
        if num_blocks <= self.num_blocks:
            return
        self._free.extend(range(self.num_blocks, num_blocks))
        self.num_blocks = num_blocks

    def check_invariants(self) -> None:
        """Raises AssertionError on a corrupted pool (tests call this
        after every operation)."""
        free = set(self._free)
        assert len(free) == len(self._free), "duplicate ids in free list"
        assert NULL_BLOCK not in free, "null block leaked into free list"
        assert not (free & set(self._ref)), "block both free and live"
        assert all(c >= 1 for c in self._ref.values()), "refcount < 1"
        assert len(free) + len(self._ref) == self.capacity, \
            "block conservation violated"


# ---------------------------------------------------------------------------
# host memory tier
# ---------------------------------------------------------------------------

def _to_host(x) -> jax.Array:
    """Commit an array to host (CPU) memory; the returned copy shares no
    buffer with the device pool."""
    try:
        return jax.device_put(x, jax.local_devices(backend="cpu")[0])
    except RuntimeError:           # no CPU backend registered (rare)
        import numpy as np
        return np.asarray(x)


class HostBlockPool:
    """LRU-bounded host memory tier for evicted prefix pages.

    When the device prefix cache LRU-evicts a cold entry, its pages are
    copied here (``jax.device_put`` to the CPU backend) instead of being
    lost outright; a later prefix hit swaps them back into freshly
    allocated device blocks (``fetch`` has move semantics — the host copy
    is consumed by the swap-in, keeping exactly one owner per page copy).
    Capacity is counted in blocks; inserting past it evicts entries in
    insertion-then-touch order, exactly like the device prefix cache, so
    the two tiers age deterministically. An entry wider than the whole
    pool is rejected (counted by the caller), not partially stored.

    Entries are verbatim snapshots: the ``(L, nb, bs, KH, D)`` k/v pages,
    the first generated token (so a swap-in skips the prefill entirely),
    and the ``(block_id, generation)`` pairs the pages were copied from —
    the generation tags prove a host copy is never an alias of a live
    device page (the source blocks have been freed, and any reuse bumps
    their generation).
    """

    def __init__(self, capacity_blocks: int):
        if capacity_blocks < 0:
            raise ValueError(
                f"negative host pool capacity {capacity_blocks}")
        self.capacity_blocks = capacity_blocks
        self._entries: "collections.OrderedDict" = collections.OrderedDict()
        self._used = 0
        self.offloads = 0
        self.evictions = 0
        self.rejected = 0

    # -- introspection ---------------------------------------------------
    @property
    def used_blocks(self) -> int:
        return self._used

    @property
    def num_entries(self) -> int:
        return len(self._entries)

    def __contains__(self, key) -> bool:
        return key in self._entries

    def keys(self) -> List:
        """Keys in eviction (insertion-then-touch) order."""
        return list(self._entries)

    # -- offload / swap-in ----------------------------------------------
    def offload(self, key, k_pages, v_pages, first: int,
                gens: Sequence[Tuple[int, int]] = ()) -> List:
        """Copy an evicted prefix entry's pages to host; returns the keys
        this insertion LRU-evicted (empty when it fit). ``k_pages`` /
        ``v_pages`` are ``(L, nb, bs, KH, D)``; ``gens`` the source pages'
        ``(block_id, generation)`` identity at offload time."""
        nb = int(k_pages.shape[1])
        if nb == 0 or self.capacity_blocks == 0:
            return []
        if nb > self.capacity_blocks:
            self.rejected += 1
            return []
        if key in self._entries:          # refresh: re-insert at MRU end
            self._used -= self._entries.pop(key)["blocks"]
        evicted = []
        while self._used + nb > self.capacity_blocks:
            old_key, old = self._entries.popitem(last=False)
            self._used -= old["blocks"]
            self.evictions += 1
            evicted.append(old_key)
        self._entries[key] = {
            "k": _to_host(k_pages), "v": _to_host(v_pages),
            "first": int(first), "gens": tuple(gens), "blocks": nb,
        }
        self._used += nb
        self.offloads += 1
        return evicted

    def fetch(self, key) -> Optional[dict]:
        """Consume an entry for swap-in (move semantics): the pages become
        device-resident again and the host copy is dropped. None on miss."""
        entry = self._entries.pop(key, None)
        if entry is not None:
            self._used -= entry["blocks"]
        return entry

    def peek(self, key) -> Optional[dict]:
        """Read an entry without consuming it or touching its LRU position
        — the prefetch engine's view (``repro.kvcache.transfer``): a
        prefetch must not pin entries against eviction, and issuing one
        must not perturb the tier's aging relative to the non-prefetching
        engine (bit-identical degradation). None on miss."""
        return self._entries.get(key)

    def touch(self, key) -> bool:
        """Refresh an entry's LRU position without consuming it."""
        if key in self._entries:
            self._entries.move_to_end(key)
            return True
        return False

    def check_invariants(self) -> None:
        """Raises AssertionError on a corrupted host pool (the stateful
        property suite calls this after every step)."""
        used = sum(e["blocks"] for e in self._entries.values())
        assert used == self._used, "host pool block accounting drifted"
        assert self._used <= self.capacity_blocks, "host pool over capacity"
        assert all(e["blocks"] >= 1 for e in self._entries.values()), \
            "empty host entry"
        for e in self._entries.values():
            assert e["k"].shape[1] == e["blocks"], "host entry shape drift"


# ---------------------------------------------------------------------------
# device data path
# ---------------------------------------------------------------------------

class PagedKVCache(NamedTuple):
    """The physical page pool, layer-stacked like the slotted KVCache so
    the decoder ``lax.scan`` consumes one layer slice per step."""
    k: jax.Array          # (L, N, block_size, KH, D)
    v: jax.Array          # (L, N, block_size, KH, D)

    @property
    def num_blocks(self) -> int:
        return self.k.shape[1]

    @property
    def block_size(self) -> int:
        return self.k.shape[2]

    @property
    def nbytes(self) -> int:
        return self.k.nbytes + self.v.nbytes


def init_paged_kv_cache(num_layers: int, num_blocks: int, block_size: int,
                        kv_heads: int, head_dim: int,
                        dtype=jnp.bfloat16) -> PagedKVCache:
    shape = (num_layers, num_blocks, block_size, kv_heads, head_dim)
    return PagedKVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def grow_paged_kv_cache(pool: PagedKVCache, num_blocks: int) -> PagedKVCache:
    """Pool with more pages; existing page contents (and ids) preserved."""
    L, N, bs, KH, D = pool.k.shape
    if num_blocks <= N:
        return pool
    pad = jnp.zeros((L, num_blocks - N, bs, KH, D), pool.k.dtype)
    return PagedKVCache(jnp.concatenate([pool.k, pad], axis=1),
                        jnp.concatenate([pool.v, pad], axis=1))


def gather_layer(pool_layer: jax.Array, table: jax.Array) -> jax.Array:
    """Rebuild a contiguous per-slot view from one layer's pool.

    pool_layer: (N, bs, KH, D); table: (B, M) int32 physical block ids
    (NULL_BLOCK padding gathers finite garbage — positions past a slot's
    length are masked to exactly-zero probability by the attention, so the
    result is bit-identical to attending the slotted cache when
    ``M * bs == max_seq``). Returns (B, M * bs, KH, D).
    """
    B, M = table.shape
    N, bs, KH, D = pool_layer.shape
    view = pool_layer[table]                     # (B, M, bs, KH, D)
    return view.reshape(B, M * bs, KH, D)


def append_layer(pool_layer: jax.Array, new: jax.Array, table: jax.Array,
                 lengths: jax.Array) -> jax.Array:
    """Scatter one new token per slot into its current page.

    pool_layer: (N, bs, KH, D); new: (B, KH, D); lengths: (B,) — token b
    lands at ``(table[b, lengths[b] // bs], lengths[b] % bs)``. Inactive
    slots' table rows are NULL, so their garbage tokens land in the null
    page. The block index is clamped like the slotted path's
    dynamic_update_slice, so an inactive slot whose stale length keeps
    growing writes to the null page instead of going out of bounds.
    """
    B, M = table.shape
    bs = pool_layer.shape[1]
    idx = jnp.clip(lengths // bs, 0, M - 1)
    blocks = table[jnp.arange(B), idx]           # (B,)
    offs = lengths % bs
    return pool_layer.at[blocks, offs].set(new.astype(pool_layer.dtype))


def write_blocks(pool: PagedKVCache, block_ids: jax.Array,
                 k_new: jax.Array, v_new: jax.Array,
                 true_len=None) -> PagedKVCache:
    """Block-granular admission write: scatter a prefilled prefix into the
    pool pages named by ``block_ids``.

    k_new/v_new: (L, S, KH, D) with S a multiple of block_size;
    block_ids: (S // block_size,) int32, NULL_BLOCK-padded past the
    prompt's last real block (those slices land in the null page).
    ``true_len`` (traced ok) zeroes positions >= true_len first, so pages
    never hold bucket-pad garbage — the paged analogue of
    ``write_slot_prefix``'s stale-KV guard.
    """
    L, S, KH, D = k_new.shape
    bs = pool.block_size
    if S % bs:
        raise ValueError(f"prefix length {S} not a multiple of "
                         f"block_size {bs}")
    nb = S // bs
    if true_len is not None:
        valid = jnp.arange(S) < true_len
        mask = valid[None, :, None, None]
        k_new = jnp.where(mask, k_new, jnp.zeros((), k_new.dtype))
        v_new = jnp.where(mask, v_new, jnp.zeros((), v_new.dtype))
    kb = k_new.reshape(L, nb, bs, KH, D).astype(pool.k.dtype)
    vb = v_new.reshape(L, nb, bs, KH, D).astype(pool.v.dtype)
    return PagedKVCache(pool.k.at[:, block_ids].set(kb),
                        pool.v.at[:, block_ids].set(vb))


def copy_block(pool: PagedKVCache, dst: jax.Array,
               src: jax.Array) -> PagedKVCache:
    """Copy-on-write: duplicate page ``src`` into page ``dst``."""
    dst = jnp.asarray(dst, jnp.int32)
    src = jnp.asarray(src, jnp.int32)
    return PagedKVCache(pool.k.at[:, dst].set(pool.k[:, src]),
                        pool.v.at[:, dst].set(pool.v[:, src]))


def extract_blocks(pool: PagedKVCache,
                   block_ids: Sequence[int]) -> Tuple[jax.Array, jax.Array]:
    """Gather the pages named by ``block_ids`` out of the pool (read-only;
    the pool is untouched). Returns (k, v) of shape (L, nb, bs, KH, D) —
    the host tier's offload payload."""
    ids = jnp.asarray(block_ids, jnp.int32)
    return pool.k[:, ids], pool.v[:, ids]


def insert_blocks(pool: PagedKVCache, block_ids: jax.Array,
                  k_pages: jax.Array, v_pages: jax.Array) -> PagedKVCache:
    """Write whole pages back into the pool at ``block_ids`` — the swap-in
    counterpart of :func:`extract_blocks`. ``k_pages``/``v_pages`` are
    (L, nb, bs, KH, D); the write is bit-exact, so a round trip through
    the host tier preserves page identity."""
    ids = jnp.asarray(block_ids, jnp.int32)
    return PagedKVCache(
        pool.k.at[:, ids].set(k_pages.astype(pool.k.dtype)),
        pool.v.at[:, ids].set(v_pages.astype(pool.v.dtype)))
