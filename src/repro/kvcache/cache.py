"""Unique (per-request) KV cache — the paper's 'Unique KV' pool.

Layout is layer-stacked so the decoder ``lax.scan`` consumes one layer slice
per step: k/v (L, B, S, KH, D), lengths (B,). Sharded batch-major at serve
time (each device owns its requests = the Unique-KV node of Fig. 3).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class KVCache(NamedTuple):
    k: jax.Array          # (L, B, S, KH, D)
    v: jax.Array          # (L, B, S, KH, D)
    length: jax.Array     # (B,) int32 — valid tokens in *this buffer*
    offset: jax.Array     # (B,) int32 — absolute position of buffer slot 0
                          # (= shared-corpus length when a store precedes it)

    @property
    def max_seq(self) -> int:
        return self.k.shape[2]

    @property
    def positions(self) -> jax.Array:
        """Absolute position of the next token per request."""
        return self.offset + self.length


def init_kv_cache(num_layers: int, batch: int, max_seq: int, kv_heads: int,
                  head_dim: int, dtype=jnp.bfloat16) -> KVCache:
    shape = (num_layers, batch, max_seq, kv_heads, head_dim)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                   jnp.zeros((batch,), jnp.int32),
                   jnp.zeros((batch,), jnp.int32))


def abstract_kv_cache(num_layers: int, batch: int, max_seq: int,
                      kv_heads: int, head_dim: int,
                      dtype=jnp.bfloat16) -> KVCache:
    shape = (num_layers, batch, max_seq, kv_heads, head_dim)
    sds = jax.ShapeDtypeStruct
    return KVCache(sds(shape, dtype), sds(shape, dtype),
                   sds((batch,), jnp.int32), sds((batch,), jnp.int32))


def write_prefix(k_layer: jax.Array, v_layer: jax.Array, new_k: jax.Array,
                 new_v: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Write a full prefix (B, S_new, KH, D) at position 0 (prefill)."""
    S_new = new_k.shape[1]
    k_layer = jax.lax.dynamic_update_slice_in_dim(
        k_layer, new_k.astype(k_layer.dtype), 0, axis=1)
    v_layer = jax.lax.dynamic_update_slice_in_dim(
        v_layer, new_v.astype(v_layer.dtype), 0, axis=1)
    return k_layer, v_layer


def append_token(k_layer: jax.Array, v_layer: jax.Array, new_k: jax.Array,
                 new_v: jax.Array, lengths: jax.Array
                 ) -> tuple[jax.Array, jax.Array]:
    """Append one token per request at its current length.

    k_layer: (B, S, KH, D); new_k: (B, KH, D); lengths: (B,).
    """
    def upd(cache_b, new_b, len_b):
        return jax.lax.dynamic_update_slice_in_dim(
            cache_b, new_b[None].astype(cache_b.dtype), len_b, axis=0)

    k_layer = jax.vmap(upd)(k_layer, new_k, lengths)
    v_layer = jax.vmap(upd)(v_layer, new_v, lengths)
    return k_layer, v_layer
