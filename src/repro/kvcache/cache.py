"""Unique (per-request) KV cache — the paper's 'Unique KV' pool.

Layout is layer-stacked so the decoder ``lax.scan`` consumes one layer slice
per step: k/v (L, B, S, KH, D), lengths (B,). Sharded batch-major at serve
time (each device owns its requests = the Unique-KV node of Fig. 3).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class KVCache(NamedTuple):
    k: jax.Array          # (L, B, S, KH, D)
    v: jax.Array          # (L, B, S, KH, D)
    length: jax.Array     # (B,) int32 — valid tokens in *this buffer*
    offset: jax.Array     # (B,) int32 — absolute position of buffer slot 0
                          # (= shared-corpus length when a store precedes it)

    @property
    def max_seq(self) -> int:
        return self.k.shape[2]

    @property
    def positions(self) -> jax.Array:
        """Absolute position of the next token per request."""
        return self.offset + self.length


def init_kv_cache(num_layers: int, batch: int, max_seq: int, kv_heads: int,
                  head_dim: int, dtype=jnp.bfloat16) -> KVCache:
    shape = (num_layers, batch, max_seq, kv_heads, head_dim)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                   jnp.zeros((batch,), jnp.int32),
                   jnp.zeros((batch,), jnp.int32))


def abstract_kv_cache(num_layers: int, batch: int, max_seq: int,
                      kv_heads: int, head_dim: int,
                      dtype=jnp.bfloat16) -> KVCache:
    shape = (num_layers, batch, max_seq, kv_heads, head_dim)
    sds = jax.ShapeDtypeStruct
    return KVCache(sds(shape, dtype), sds(shape, dtype),
                   sds((batch,), jnp.int32), sds((batch,), jnp.int32))


def write_slot_prefix(cache: KVCache, slot_cache: KVCache, slot,
                      true_len=None) -> KVCache:
    """Write a prefilled 1-batch cache into batch slot ``slot`` in place.

    The donation-friendly per-slot admission write: jit the caller with
    ``donate_argnums`` on ``cache`` and XLA updates the batch cache buffer
    without copying the other ``B - 1`` slots (vs. the full-cache merge of
    a ``tree_map``-style copy).

    ``slot_cache`` holds a (L, 1, S_new, KH, D) prefix with S_new <=
    cache.max_seq (S_new may be a padded prefill bucket). ``true_len``
    (traced scalar ok), when given, is the real prompt length: positions
    >= true_len inside the prefix are zeroed and the slot length is set to
    ``true_len``, so a reused slot never leaks stale or pad KV beyond the
    new prompt. The slot tail beyond S_new is always zeroed.
    """
    S, S_new = cache.max_seq, slot_cache.max_seq
    if S_new > S:
        raise ValueError(f"slot prefix length {S_new} > cache max_seq {S}")
    slot = jnp.asarray(slot, jnp.int32)

    def wr(dst, src):
        src = src.astype(dst.dtype)
        if true_len is not None:
            valid = jnp.arange(S_new) < true_len
            src = jnp.where(valid[None, None, :, None, None], src,
                            jnp.zeros((), dst.dtype))
        if S > S_new:
            pad = jnp.zeros(src.shape[:2] + (S - S_new,) + src.shape[3:],
                            dst.dtype)
            src = jnp.concatenate([src, pad], axis=2)
        return jax.lax.dynamic_update_slice(dst, src, (0, slot, 0, 0, 0))

    length = (slot_cache.length[0] if true_len is None
              else jnp.asarray(true_len, jnp.int32))
    return KVCache(wr(cache.k, slot_cache.k), wr(cache.v, slot_cache.v),
                   cache.length.at[slot].set(length),
                   cache.offset.at[slot].set(slot_cache.offset[0]))


def read_slot(cache: KVCache, slot: int) -> KVCache:
    """1-batch view of slot ``slot`` (tests / debugging)."""
    return KVCache(cache.k[:, slot:slot + 1], cache.v[:, slot:slot + 1],
                   cache.length[slot:slot + 1], cache.offset[slot:slot + 1])


def write_prefix(k_layer: jax.Array, v_layer: jax.Array, new_k: jax.Array,
                 new_v: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Write a full prefix (B, S_new, KH, D) at position 0 (prefill)."""
    S_new = new_k.shape[1]
    k_layer = jax.lax.dynamic_update_slice_in_dim(
        k_layer, new_k.astype(k_layer.dtype), 0, axis=1)
    v_layer = jax.lax.dynamic_update_slice_in_dim(
        v_layer, new_v.astype(v_layer.dtype), 0, axis=1)
    return k_layer, v_layer


def append_token(k_layer: jax.Array, v_layer: jax.Array, new_k: jax.Array,
                 new_v: jax.Array, lengths: jax.Array
                 ) -> tuple[jax.Array, jax.Array]:
    """Append one token per request at its current length.

    k_layer: (B, S, KH, D); new_k: (B, KH, D); lengths: (B,).
    """
    def upd(cache_b, new_b, len_b):
        return jax.lax.dynamic_update_slice_in_dim(
            cache_b, new_b[None].astype(cache_b.dtype), len_b, axis=0)

    k_layer = jax.vmap(upd)(k_layer, new_k, lengths)
    v_layer = jax.vmap(upd)(v_layer, new_v, lengths)
    return k_layer, v_layer
