from repro.kvcache.cache import (  # noqa: F401
    KVCache, abstract_kv_cache, append_token, init_kv_cache, read_slot,
    write_prefix, write_slot_prefix,
)
