from repro.kvcache.cache import (  # noqa: F401
    KVCache, abstract_kv_cache, append_token, init_kv_cache, read_slot,
    write_prefix, write_slot_prefix,
)
from repro.kvcache.block_table import (  # noqa: F401
    NULL_BLOCK, SlotTables, blocks_for, validate_block_size,
)
from repro.kvcache.paged import (  # noqa: F401
    BlockPool, HostBlockPool, PagedKVCache, PoolExhausted, append_layer,
    copy_block, extract_blocks, gather_layer, grow_paged_kv_cache,
    init_paged_kv_cache, insert_blocks, write_blocks,
)
from repro.kvcache.transfer import PrefetchEngine  # noqa: F401
