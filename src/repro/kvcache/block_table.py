"""Host-side block tables for the paged KV cache.

A block table maps a request slot's *logical* token positions onto
*physical* blocks of the shared block pool (``repro.kvcache.paged``):
token ``t`` of slot ``b`` lives at ``(table[b, t // block_size],
t % block_size)``. Tables are small host ``numpy`` arrays mutated by the
engine between decode waves and shipped to device as plain ``int32``
operands of the jit'd paged decode step — the static ``(B, M)`` shape keeps
the compiled program stable while the mapping underneath changes freely.

Physical block 0 is the **null block** (``NULL_BLOCK``): table rows of
inactive slots point at it, so the decode wave's garbage lanes scatter
their writes into a sacrificial page instead of corrupting live blocks,
and padded table entries gather finite garbage that the attention masks
out exactly (see ``paged.gather_layer``).
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

#: physical block id reserved as the write sink / gather filler.
NULL_BLOCK = 0


def blocks_for(n_tokens: int, block_size: int) -> int:
    """Number of blocks covering ``n_tokens`` tokens (ceil division)."""
    if n_tokens < 0:
        raise ValueError(f"negative token count {n_tokens}")
    return -(-n_tokens // block_size)


class SlotTables:
    """Per-slot block tables + lengths/offsets, host side.

    The engine mutates these between waves (admission, on-demand block
    append, copy-on-write swaps, release) and snapshots them with
    :meth:`device_args` for each jit'd decode step.
    """

    def __init__(self, num_slots: int, blocks_per_slot: int,
                 block_size: int):
        if blocks_per_slot < 1:
            raise ValueError("blocks_per_slot must be >= 1")
        self.block_size = block_size
        self.table = np.full((num_slots, blocks_per_slot), NULL_BLOCK,
                             np.int32)
        self.length = np.zeros((num_slots,), np.int32)
        self.offset = np.zeros((num_slots,), np.int32)
        # blocks actually allocated per slot (NULL padding is not counted)
        self.n_blocks = np.zeros((num_slots,), np.int32)

    @property
    def num_slots(self) -> int:
        return self.table.shape[0]

    @property
    def blocks_per_slot(self) -> int:
        return self.table.shape[1]

    @property
    def capacity_tokens(self) -> int:
        return self.blocks_per_slot * self.block_size

    # ------------------------------------------------------------------
    def assign(self, slot: int, block_ids: Sequence[int], length: int,
               offset: int) -> None:
        """Install a freshly admitted request's prompt blocks."""
        ids = list(block_ids)
        if len(ids) > self.blocks_per_slot:
            raise ValueError(
                f"{len(ids)} blocks exceed table width "
                f"{self.blocks_per_slot}")
        row = self.table[slot]
        row[:] = NULL_BLOCK
        row[:len(ids)] = ids
        self.length[slot] = length
        self.offset[slot] = offset
        self.n_blocks[slot] = len(ids)

    def append_block(self, slot: int, block_id: int) -> None:
        n = int(self.n_blocks[slot])
        if n >= self.blocks_per_slot:
            raise ValueError(f"slot {slot} table full ({n} blocks)")
        self.table[slot, n] = block_id
        self.n_blocks[slot] = n + 1

    def replace_block(self, slot: int, index: int, block_id: int) -> None:
        """Swap one mapping in place (copy-on-write)."""
        if index >= int(self.n_blocks[slot]):
            raise ValueError(f"slot {slot} has no block at index {index}")
        self.table[slot, index] = block_id

    def slot_blocks(self, slot: int) -> List[int]:
        return self.table[slot, : int(self.n_blocks[slot])].tolist()

    def prefix_blocks(self, slot: int, n_tokens: int) -> List[int]:
        """Block ids covering the slot's first ``n_tokens`` tokens — the
        prompt prefix a released request parks in the prefix cache (and
        the unit the host tier offloads). Empty when the slot maps fewer
        blocks than the prefix needs (e.g. already released)."""
        nb = blocks_for(n_tokens, self.block_size)
        if nb > int(self.n_blocks[slot]):
            return []
        return self.table[slot, :nb].tolist()

    def clear(self, slot: int) -> List[int]:
        """Release a slot's mapping; returns the block ids it held.

        The slot's ``length``/``offset`` are deliberately NOT reset: the
        decode wave keeps computing garbage for inactive slots, and for
        bit-identity with the slotted layout those lanes must see the same
        (stale) positions the slotted cache would.
        """
        ids = self.slot_blocks(slot)
        self.table[slot, :] = NULL_BLOCK
        self.n_blocks[slot] = 0
        return ids

    def block_index(self, slot: int, position: int) -> int:
        """Table index of the block holding logical token ``position``."""
        idx = position // self.block_size
        if idx >= self.blocks_per_slot:
            raise ValueError(
                f"position {position} beyond slot capacity "
                f"{self.capacity_tokens}")
        return idx

    def grow(self, blocks_per_slot: int) -> None:
        """Widen every table row (longer max request); existing mappings
        are preserved."""
        if blocks_per_slot <= self.blocks_per_slot:
            return
        pad = np.full((self.num_slots,
                       blocks_per_slot - self.blocks_per_slot),
                      NULL_BLOCK, np.int32)
        self.table = np.concatenate([self.table, pad], axis=1)

    def tick(self) -> None:
        """Advance one decode wave: every slot's length grows by one, the
        exact mirror of the slotted decode step's ``cache.length + 1``
        (inactive slots included, so their garbage lanes stay bit-identical
        across layouts)."""
        self.length += 1

    def device_args(self):
        """(table, length, offset) copies for one jit'd decode step."""
        return (self.table.copy(), self.length.copy(), self.offset.copy())


def validate_block_size(block_size: int, max_seq: int) -> None:
    """Engine-facing constraint: the paged gather view must tile max_seq
    exactly so the paged attention program has the same shape as the
    slotted one (this is what makes paged-vs-slotted bit-identical)."""
    if block_size < 1:
        raise ValueError(f"block_size must be >= 1, got {block_size}")
    if max_seq % block_size:
        raise ValueError(
            f"block_size {block_size} must divide max_seq {max_seq} "
            "(the paged gather view tiles max_seq exactly)")
