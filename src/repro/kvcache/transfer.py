"""Async host->device page transfers for the paged serving hot path.

The host tier (``HostBlockPool``) made cold prefix hits cheap in *tokens*
(swap pages back instead of re-prefilling) but not in *time*: the swap-in
still moves pages up synchronously, stalling the admission that needs
them. :class:`PrefetchEngine` issues those copies early — during a decode
wave, for the prefix entries the scheduler's lookahead predicts will be
admitted next — so by the time ``_prefill_slot_paged`` runs, the pages
are already device-resident (or at worst mid-flight, a bounded wait).

Mechanics, and why this is safe:

  * ``issue(key)`` peeks the host entry (non-consuming, LRU-neutral: a
    prefetch never pins an entry against eviction nor perturbs the
    tier's aging) and calls ``jax.device_put`` on its pages. JAX async
    dispatch returns immediately — the copy proceeds while the host
    thread keeps working and the device decodes. In-flight transfers are
    bounded by ``depth``.
  * A transfer carries the entry's generation-tagged page identity (the
    ``(block_id, generation)`` pairs stamped at offload time). Host
    entries are immutable snapshots, so the transferred pages can never
    alias a live device page — but the *key* can be re-offloaded with
    different pages after the tier churned. The consumer therefore
    matches generations: ``take(key)`` resolves against the entry
    actually fetched, and a mismatch means the transfer belongs to a
    dead lifetime — discard it and swap in the current entry (the
    values are bit-identical either way; the generations are the proof
    of identity, not the contents).
  * ``sweep()`` drops in-flight transfers whose host entry was evicted
    or replaced (stale generations) so a bounded ``depth`` is never
    clogged by dead transfers. Dropping a jax array just releases the
    buffer; an incomplete copy is cancelled by the runtime.

Degradation contract: with the engine's prefetch depth at 0 (or no host
tier) nothing here runs and the swap-in path is byte-for-byte the PR 9
synchronous one. With prefetching on, the only observable differences
are timing and the ``kvcache/prefetch_{issued,hits,wasted}`` counters —
generations are bit-identical.
"""
from __future__ import annotations

import collections
from typing import List, Optional

import jax

from repro.kvcache.paged import HostBlockPool


class PrefetchEngine:
    """Bounded pool of in-flight host->device page transfers, keyed like
    the prefix cache by ``(corpus fingerprint, prompt)``."""

    def __init__(self, host_pool: HostBlockPool, depth: int,
                 device=None):
        if depth < 0:
            raise ValueError(f"negative prefetch depth {depth}")
        self.host_pool = host_pool
        self.depth = depth
        self._device = device
        # key -> {"k", "v": device arrays (possibly still transferring),
        #         "first": int, "gens": ((block, gen), ...), "blocks": nb}
        self._inflight: "collections.OrderedDict" = collections.OrderedDict()
        self.issued = 0
        self.resolved = 0
        self.discarded = 0

    # -- introspection ---------------------------------------------------
    @property
    def in_flight(self) -> int:
        return len(self._inflight)

    def __contains__(self, key) -> bool:
        return key in self._inflight

    def keys(self) -> List:
        return list(self._inflight)

    # -- issue / resolve -------------------------------------------------
    def issue(self, key) -> bool:
        """Start an async device copy of the host entry at ``key``.
        Returns False (no copy) when the key is already in flight, the
        depth budget is full, or the host tier has no such entry."""
        if self.depth <= 0 or key in self._inflight or \
                len(self._inflight) >= self.depth:
            return False
        entry = self.host_pool.peek(key)
        if entry is None:
            return False
        if self._device is None:
            self._device = jax.devices()[0]
        # async: device_put dispatches the copy and returns futures
        self._inflight[key] = {
            "k": jax.device_put(entry["k"], self._device),
            "v": jax.device_put(entry["v"], self._device),
            "first": entry["first"],
            "gens": entry["gens"],
            "blocks": entry["blocks"],
        }
        self.issued += 1
        return True

    def take(self, key) -> Optional[dict]:
        """Claim the in-flight transfer for ``key`` (None when there is
        none). The caller owns generation matching: compare the returned
        ``gens`` against the host entry it fetched, and discard the
        transfer on mismatch (a stale lifetime)."""
        tr = self._inflight.pop(key, None)
        if tr is not None:
            self.resolved += 1
        return tr

    def discard(self, key) -> bool:
        """Drop one in-flight transfer (its device buffers are released;
        an incomplete copy is cancelled by the runtime)."""
        if self._inflight.pop(key, None) is not None:
            self.discarded += 1
            return True
        return False

    def sweep(self) -> int:
        """Discard in-flight transfers whose host entry disappeared or
        was replaced (generation mismatch) since issue — they can never
        resolve to a hit. Returns how many were dropped."""
        stale = []
        for key, tr in self._inflight.items():
            entry = self.host_pool.peek(key)
            if entry is None or entry["gens"] != tr["gens"]:
                stale.append(key)
        for key in stale:
            self.discard(key)
        return len(stale)

    def clear(self) -> int:
        """Drop every in-flight transfer (engine teardown / tier reset)."""
        n = len(self._inflight)
        for key in list(self._inflight):
            self.discard(key)
        return n

    def check_invariants(self) -> None:
        """Raises AssertionError on a corrupted prefetch state (the
        stateful property suite calls this after every step)."""
        assert len(self._inflight) <= max(self.depth, 0), \
            "prefetch depth exceeded"
        assert self.resolved + self.discarded + len(self._inflight) \
            == self.issued, "prefetch accounting drifted"
        for key, tr in self._inflight.items():
            assert tr["k"].shape[1] == tr["blocks"], \
                f"in-flight transfer {key!r} shape drift"
