"""Config registry: ``get_config(arch_id)`` / ``list_archs()``.

Arch ids use the assignment's dashed names (e.g. ``qwen1.5-0.5b``).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (  # noqa: F401 (public re-exports)
    AUDIO, DENSE, FAMILIES, HYBRID, MOE, SSM, VLM,
    DECODE_32K, INPUT_SHAPES, LONG_500K, PREFILL_32K, TRAIN_4K,
    EncoderConfig, HybridConfig, InputShape, MoEConfig, ModelConfig,
    MoSKAConfig, SSMConfig,
)

_ARCH_MODULES: Dict[str, str] = {
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "llama3-8b": "llama3_8b",
    "mistral-large-123b": "mistral_large_123b",
    "internvl2-76b": "internvl2_76b",
    "arctic-480b": "arctic_480b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "mamba2-130m": "mamba2_130m",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "whisper-tiny": "whisper_tiny",
    # the paper's own model (not part of the assigned 10)
    "moska-llama3.1-8b": "moska_llama31_8b",
}

ASSIGNED_ARCHS: List[str] = [k for k in _ARCH_MODULES if k != "moska-llama3.1-8b"]


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(
            f"unknown arch {arch!r}; available: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.CONFIG


def list_archs() -> List[str]:
    return list(_ARCH_MODULES)


def get_shape(name: str) -> InputShape:
    return INPUT_SHAPES[name]
