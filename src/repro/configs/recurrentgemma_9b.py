"""recurrentgemma-9b — RG-LRU + local attention hybrid, 1 attn : 2 recurrent
[arXiv:2402.19427].

MoSKA partial applicability (DESIGN.md): attention layers use per-request
sliding windows; MoSKA routed shared attention is exposed as an optional
extra path (default off, Griffin-faithful).
"""
from repro.configs.base import ModelConfig, HybridConfig, MoSKAConfig, HYBRID

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family=HYBRID,
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,      # MQA
    d_ff=12288,
    vocab_size=256000,
    head_dim=256,
    rope_theta=10000.0,
    source="arXiv:2402.19427",
    hybrid=HybridConfig(pattern=("rglru", "rglru", "attn"), window=2048),
    moska=MoSKAConfig(enabled=False),
)
