"""Config system for the MoSKA reproduction framework.

Every architecture in the assigned pool is expressed as a ``ModelConfig``.
Configs are plain frozen dataclasses so they hash, compare, and serialize
cleanly; ``reduced()`` produces the CPU-smoke variant mandated by the
assignment (<=2 layers, d_model<=512, <=4 experts).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

# ---------------------------------------------------------------------------
# Architecture families
# ---------------------------------------------------------------------------

DENSE = "dense"
MOE = "moe"
SSM = "ssm"
HYBRID = "hybrid"
VLM = "vlm"
AUDIO = "audio"

FAMILIES = (DENSE, MOE, SSM, HYBRID, VLM, AUDIO)


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts FFN configuration (dropping / capacity-based)."""

    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # Arctic keeps a dense FFN residual path in parallel with the experts.
    dense_residual: bool = False
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01

    @property
    def enabled(self) -> bool:
        return self.num_experts > 0


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD, state-space duality) block configuration."""

    state_dim: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk_size: int = 256  # SSD block size for the chunked-scan algorithm

    @property
    def enabled(self) -> bool:
        return self.state_dim > 0


@dataclass(frozen=True)
class HybridConfig:
    """RecurrentGemma / Griffin-style hybrid configuration.

    ``pattern`` is a tuple over the layer cycle, e.g. ("rglru", "rglru",
    "attn") is the Griffin 1-attention-per-3 pattern. Attention layers use a
    local sliding window.
    """

    pattern: Tuple[str, ...] = ()
    window: int = 2048
    lru_width: Optional[int] = None  # defaults to d_model

    @property
    def enabled(self) -> bool:
        return len(self.pattern) > 0


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder tower for enc-dec (audio) and VLM architectures.

    The modality frontend (mel+conv for audio, ViT for vision) is a STUB per
    the assignment: ``input_specs`` hands the backbone precomputed frame /
    patch embeddings of shape (batch, frontend_seq, frontend_dim).
    """

    num_layers: int = 0
    frontend_seq: int = 0  # frames (audio) or patches (vision)
    frontend_dim: int = 0  # embedding dim delivered by the stub frontend
    is_causal: bool = False

    @property
    def enabled(self) -> bool:
        return self.num_layers > 0 or self.frontend_seq > 0


@dataclass(frozen=True)
class MoSKAConfig:
    """The paper's technique: shared-KV chunk store + routed GEMM attention."""

    enabled: bool = True
    chunk_size: int = 2048          # tokens per shared chunk ("expert")
    top_k_chunks: int = 8           # chunks selected per query group
    # paper evaluates 75% sparsity => top_k/num_chunks ~ 0.25 at eval time
    sparsity: float = 0.75
    query_capacity_factor: float = 2.0  # per-chunk query batching capacity
    router: str = "mean_key"        # chunk embedding = mean of chunk keys
    # Apply MoSKA to shared context at decode; unique KV stays GEMV path.
    max_shared_tokens: int = 16 * 1024 * 1024
    kv_quant: str = "none"          # none | int8 (capacity parity w/ FP8)

    @property
    def keep_fraction(self) -> float:
        return 1.0 - self.sparsity


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 => d_model // num_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    rms_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=lambda: SSMConfig(state_dim=0))
    hybrid: HybridConfig = field(default_factory=HybridConfig)
    encoder: EncoderConfig = field(default_factory=EncoderConfig)
    moska: MoSKAConfig = field(default_factory=MoSKAConfig)
    # provenance: paper / model card the config was taken from
    source: str = ""
    # sliding-window for dense archs that opt into sub-quadratic attention
    attn_window: int = 0            # 0 => full causal attention
    # §Perf knobs: flash-attention KV block (train/prefill) + remat policy
    attn_block_k: int = 1024
    remat_policy: str = "nothing"   # nothing | dots | none

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.family not in FAMILIES:
            raise ValueError(f"unknown family {self.family!r}")
        if self.num_heads and self.num_kv_heads:
            if self.num_heads % self.num_kv_heads:
                raise ValueError(
                    f"{self.name}: num_heads {self.num_heads} not divisible by "
                    f"kv heads {self.num_kv_heads}")

    # ------------------------------------------------------------------
    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def attention_free(self) -> bool:
        return self.family == SSM

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have a decode path (enc-dec included)

    @property
    def kv_bytes_per_token(self) -> int:
        """KV cache bytes per token (bf16 unless int8-quantized)."""
        if self.attention_free:
            return 0
        itemsize = 1 if self.moska.kv_quant == "int8" else 2
        n_attn_layers = self.num_attention_layers
        return 2 * n_attn_layers * self.num_kv_heads * self.head_dim * itemsize

    @property
    def num_attention_layers(self) -> int:
        if self.family == SSM:
            return 0
        if self.hybrid.enabled:
            cyc = self.hybrid.pattern
            full, rem = divmod(self.num_layers, len(cyc))
            return full * sum(1 for p in cyc if p == "attn") + sum(
                1 for p in cyc[:rem] if p == "attn")
        return self.num_layers

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + stacked blocks)."""
        d, f, L, V = self.d_model, self.d_ff, self.num_layers, self.vocab_size
        hd, H, KH = self.head_dim, self.num_heads, self.num_kv_heads
        emb = V * d * (1 if self.tie_embeddings else 2)
        total = emb
        if self.family == SSM:
            di = d * self.ssm.expand
            nheads = di // self.ssm.head_dim
            per = (d * (2 * di + 2 * self.ssm.state_dim * (di // self.ssm.head_dim) // max(1, di // self.ssm.head_dim)) )
            # in_proj: d -> (2*di + 2*ngroups*state + nheads); out_proj di->d
            per = d * (2 * di + 2 * self.ssm.state_dim + nheads) + di * d
            per += di * self.ssm.conv_width + nheads * 2 + 2 * d  # conv, A/D, norms
            total += L * per
            return total
        attn = d * (H * hd) + 2 * d * (KH * hd) + (H * hd) * d
        ffn_dense = 3 * d * f  # gate, up, down (SwiGLU)
        per_layer = attn + 2 * d  # + norms
        if self.moe.enabled:
            expert = 3 * d * f
            per_layer += self.moe.num_experts * expert + d * self.moe.num_experts
            if self.moe.dense_residual:
                per_layer += ffn_dense
        elif self.hybrid.enabled:
            pass  # handled below per pattern
        else:
            per_layer += ffn_dense
        if self.hybrid.enabled:
            lw = self.hybrid.lru_width or d
            rglru = d * (2 * lw) + lw * d + 3 * lw  # in/out proj + gates
            cyc = self.hybrid.pattern
            n_attn = self.num_attention_layers
            n_rec = L - n_attn
            total += n_attn * (attn + ffn_dense + 2 * d)
            total += n_rec * (rglru + ffn_dense + 2 * d)
        else:
            total += L * per_layer
        if self.encoder.num_layers > 0:  # enc-dec only (VLM embeds inline)
            e_attn = 4 * d * d
            e_ffn = 2 * d * f  # whisper uses GELU MLP (2 mats)
            total += self.encoder.num_layers * (e_attn + e_ffn + 2 * d)
            total += self.num_layers * e_attn  # decoder cross-attention
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE activates top_k of num_experts)."""
        if not self.moe.enabled:
            return self.param_count()
        d, f, L = self.d_model, self.d_ff, self.num_layers
        expert = 3 * d * f
        inactive = (self.moe.num_experts - self.moe.top_k) * expert * L
        return self.param_count() - inactive

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """CPU-smoke variant: <=2 layers, d_model<=512, <=4 experts."""
        d = min(self.d_model, 256)
        heads = max(1, min(self.num_heads, 4))
        kv = max(1, min(self.num_kv_heads, heads))
        while heads % kv:
            kv -= 1
        layers = min(self.num_layers, 2)
        if self.hybrid.enabled:
            layers = min(self.num_layers, len(self.hybrid.pattern))
        kw: Dict[str, Any] = dict(
            name=self.name + "-reduced",
            num_layers=layers,
            d_model=d,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=d // heads,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            moska=dataclasses.replace(
                self.moska, chunk_size=64, top_k_chunks=2,
                max_shared_tokens=4096),
        )
        if self.moe.enabled:
            kw["moe"] = dataclasses.replace(
                self.moe, num_experts=min(self.moe.num_experts, 4),
                top_k=min(self.moe.top_k, 2))
        if self.ssm.enabled:
            kw["ssm"] = dataclasses.replace(
                self.ssm, state_dim=32, head_dim=32, chunk_size=32)
        if self.hybrid.enabled:
            kw["hybrid"] = dataclasses.replace(self.hybrid, window=64)
        if self.encoder.enabled:
            kw["encoder"] = dataclasses.replace(
                self.encoder, num_layers=min(self.encoder.num_layers, 2),
                frontend_seq=min(self.encoder.frontend_seq or 64, 64),
                frontend_dim=d)
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = InputShape("train_4k", 4096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32768, 128, "decode")
LONG_500K = InputShape("long_500k", 524288, 1, "decode")

INPUT_SHAPES: Dict[str, InputShape] = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}
