"""qwen1.5-0.5b — dense GQA decoder with QKV bias [hf:Qwen/Qwen1.5-0.5B]."""
from repro.configs.base import ModelConfig, DENSE

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family=DENSE,
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,   # MHA (kv=16)
    d_ff=2816,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen1.5-0.5B",
)
