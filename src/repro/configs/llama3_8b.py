"""llama3-8b — dense GQA, 128k vocab [arXiv:2407.21783].

This is the paper's own evaluation model family (Llama 3.1 8B); it is the
primary MoSKA hillclimb target.
"""
from repro.configs.base import ModelConfig, MoSKAConfig, DENSE

CONFIG = ModelConfig(
    name="llama3-8b",
    family=DENSE,
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=500000.0,
    source="arXiv:2407.21783",
    moska=MoSKAConfig(enabled=True, chunk_size=2048, top_k_chunks=8,
                      sparsity=0.75),
)
