"""internvl2-76b — VLM: InternViT (stub) + InternLM2-76B LM [arXiv:2404.16821].

Per the assignment, the vision frontend (InternViT-6B + MLP projector) is a
STUB: ``input_specs()`` provides precomputed patch embeddings of shape
(batch, num_patches, d_model); this config describes the language backbone.
"""
from repro.configs.base import ModelConfig, EncoderConfig, VLM

CONFIG = ModelConfig(
    name="internvl2-76b",
    family=VLM,
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    rope_theta=1_000_000.0,
    source="arXiv:2404.16821",
    encoder=EncoderConfig(num_layers=0, frontend_seq=256, frontend_dim=8192),
)
