"""moska-llama3.1-8b — the paper's own evaluation configuration (§IV).

Llama 3.1 8B backbone with the full MoSKA feature set at the paper's
operating point: 75% sparsity, 2048-token shared chunks, 64K unique
context + 1M..16M shared corpus.
"""
import dataclasses

from repro.configs.base import MoSKAConfig
from repro.configs.llama3_8b import CONFIG as _LLAMA3

CONFIG = dataclasses.replace(
    _LLAMA3,
    name="moska-llama3.1-8b",
    moska=MoSKAConfig(
        enabled=True,
        chunk_size=2048,
        top_k_chunks=8,
        sparsity=0.75,
        query_capacity_factor=2.0,
        max_shared_tokens=16 * 1024 * 1024,
    ),
)
