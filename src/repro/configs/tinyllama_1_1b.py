"""tinyllama-1.1b — llama2-arch small dense GQA [arXiv:2401.02385]."""
from repro.configs.base import ModelConfig, DENSE

CONFIG = ModelConfig(
    name="tinyllama-1.1b",
    family=DENSE,
    num_layers=22,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    d_ff=5632,
    vocab_size=32000,
    rope_theta=10000.0,
    source="arXiv:2401.02385",
)
