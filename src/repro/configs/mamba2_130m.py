"""mamba2-130m — attention-free SSD (state-space duality) [arXiv:2405.21060].

MoSKA inapplicability (DESIGN.md §Arch-applicability): there is no KV cache;
the analogue implemented is a shared warm-start SSM state for shared
prefixes (``repro.models.ssm.shared_state``).
"""
from repro.configs.base import ModelConfig, MoSKAConfig, SSMConfig, SSM

CONFIG = ModelConfig(
    name="mamba2-130m",
    family=SSM,
    num_layers=24,
    d_model=768,
    num_heads=0,        # attention-free
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    source="arXiv:2405.21060",
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4,
                  chunk_size=256),
    moska=MoSKAConfig(enabled=False),
)
