"""granite-moe-1b-a400m — 32-expert top-8 MoE
[hf:ibm-granite/granite-3.0-1b-a400m-base]."""
from repro.configs.base import ModelConfig, MoEConfig, MOE

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family=MOE,
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=512,           # per-expert FFN width
    vocab_size=49155,
    rope_theta=10000.0,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    moe=MoEConfig(num_experts=32, top_k=8, capacity_factor=1.25),
)
