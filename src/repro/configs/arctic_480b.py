"""arctic-480b — 128-expert top-2 MoE with dense residual
[hf:Snowflake/snowflake-arctic-base]."""
from repro.configs.base import ModelConfig, MoEConfig, MOE

CONFIG = ModelConfig(
    name="arctic-480b",
    family=MOE,
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=4864,          # per-expert FFN width
    vocab_size=32000,
    rope_theta=10000.0,
    source="hf:Snowflake/snowflake-arctic-base",
    moe=MoEConfig(num_experts=128, top_k=2, capacity_factor=1.25,
                  dense_residual=True),
)
