"""whisper-tiny — encoder-decoder audio model, conv frontend stubbed
[arXiv:2212.04356].

The mel-spectrogram + conv1d feature extractor is a STUB per the assignment:
``input_specs()`` provides precomputed frame embeddings (batch, 1500, 384).
MoSKA partial applicability: cross-attention KV (shared encoder output) is
the shared cache when many requests decode against the same audio corpus.
"""
from repro.configs.base import ModelConfig, EncoderConfig, MoSKAConfig, AUDIO

CONFIG = ModelConfig(
    name="whisper-tiny",
    family=AUDIO,
    num_layers=4,        # decoder layers
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    qkv_bias=True,
    source="arXiv:2212.04356",
    encoder=EncoderConfig(num_layers=4, frontend_seq=1500, frontend_dim=384),
    moska=MoSKAConfig(enabled=True, chunk_size=375, top_k_chunks=2),
)
