"""Shared building blocks: norms, RoPE, attention (flash-style blocked,
sliding-window, decode), SwiGLU MLP.

All functions are pure; parameters are dict pytrees. Activations default to
bf16 with fp32 softmax/norm accumulation. ``lsc`` annotates logical sharding
and is the identity when no rules are installed.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sharding import lsc

DEFAULT_BLOCK_K = 1024
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Norms / MLP
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def geglu_mlp(x: jax.Array, p: dict) -> jax.Array:
    h = jnp.einsum("...d,df->...f", x, p["w_gate"])
    u = jnp.einsum("...d,df->...f", x, p["w_up"])
    h = jax.nn.gelu(h) * u
    h = lsc(h, None, None, "d_ff")
    return jnp.einsum("...f,fd->...d", h, p["w_down"])


def swiglu_mlp(x: jax.Array, p: dict) -> jax.Array:
    h = jnp.einsum("...d,df->...f", x, p["w_gate"])
    u = jnp.einsum("...d,df->...f", x, p["w_up"])
    h = jax.nn.silu(h) * u
    h = lsc(h, None, None, "d_ff")
    return jnp.einsum("...f,fd->...d", h, p["w_down"])


def gelu_mlp(x: jax.Array, p: dict) -> jax.Array:
    h = jnp.einsum("...d,df->...f", x, p["w_up"])
    h = jax.nn.gelu(h)
    return jnp.einsum("...f,fd->...d", h, p["w_down"])


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)  # (head_dim/2,)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    dt = x.dtype
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (...,S,hd/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dt)


# ---------------------------------------------------------------------------
# Blocked flash-style attention (pure jnp; the Pallas kernels in
# repro.kernels are the TPU fast path, these are the reference/XLA path)
# ---------------------------------------------------------------------------

def flash_attention(
    q: jax.Array,                # (B, Sq, H, D)
    k: jax.Array,                # (B, Sk, KH, D)
    v: jax.Array,                # (B, Sk, KH, D)
    *,
    causal: bool = True,
    q_offset: jax.Array | int = 0,   # absolute position of q[0]
    kv_offset: jax.Array | int = 0,  # absolute position of k[0]
    kv_len: Optional[jax.Array] = None,  # scalar valid kv length
    window: int = 0,             # >0: sliding-window attention
    block_k: int = DEFAULT_BLOCK_K,
    return_lse: bool = False,
):
    """Online-softmax blocked attention; scans over KV blocks.

    Memory-safe for 32K+ sequences: live buffers are O(Sq * block_k) per
    (batch, head) rather than O(Sq * Sk).

    GQA is handled by repeating KV heads to the full H (the standard
    production layout): every intermediate then carries a flat head dim
    that shards cleanly over the model axis. The earlier (B,KH,G,...)
    grouped layout made SPMD split the model axis across two tensor dims
    (e.g. 8x2 of 16), which the backward pass could not reshard without
    XLA's "involuntary full rematerialization" fallback — replicating
    global-batch activations (§Perf, mistral hillclimb iteration 2).
    """
    B, Sq, H, D = q.shape
    _, Sk, KH, _ = k.shape
    G = H // KH
    scale = 1.0 / math.sqrt(D)

    nblk = max(1, (Sk + block_k - 1) // block_k)
    pad = nblk * block_k - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    if G > 1:
        k = jnp.repeat(k, G, axis=2)                        # (B, Sk, H, D)
        v = jnp.repeat(v, G, axis=2)
    kb = k.reshape(B, nblk, block_k, H, D).swapaxes(0, 1)
    vb = v.reshape(B, nblk, block_k, H, D).swapaxes(0, 1)

    q_pos = jnp.asarray(q_offset) + jnp.arange(Sq)          # (Sq,)
    valid_len = Sk if kv_len is None else kv_len

    def body(carry, blk):
        m, l, acc, idx = carry
        kblk, vblk = blk
        k_idx = idx * block_k + jnp.arange(block_k)   # local buffer index
        k_pos = jnp.asarray(kv_offset) + k_idx        # absolute position
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kblk,
                       preferred_element_type=jnp.float32) * scale
        if causal:
            mask = k_pos[None, :] <= q_pos[:, None]
        else:
            mask = jnp.ones((Sq, block_k), bool)
        if window:
            mask &= k_pos[None, :] > (q_pos[:, None] - window)
        mask &= (k_idx < valid_len)[None, :]
        s = jnp.where(mask[None, None], s, NEG_INF)          # (B,H,Sq,Bk)
        m_blk = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m, m_blk)
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhqk,bkhd->bhqd", p.astype(vblk.dtype), vblk,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new, idx + 1), None

    m0 = jnp.full((B, H, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, D), jnp.float32)
    (m, l, acc, _), _ = jax.lax.scan(body, (m0, l0, a0, jnp.int32(0)),
                                     (kb, vb))
    l_safe = jnp.maximum(l, 1e-37)
    out = jnp.transpose(acc / l_safe[..., None], (0, 2, 1, 3))  # (B,Sq,H,D)
    out = out.astype(q.dtype)
    if return_lse:
        lse = jnp.transpose(m + jnp.log(l_safe), (0, 2, 1))  # (B,Sq,H)
        return out, lse
    return out


def decode_attention(
    q: jax.Array,          # (B, H, D) one new token per request
    k_cache: jax.Array,    # (B, S, KH, D)
    v_cache: jax.Array,    # (B, S, KH, D)
    kv_len: jax.Array,     # (B,) valid lengths
    *,
    window: int = 0,
    return_lse: bool = False,
):
    """Single-token decode attention over a per-request (unique) KV cache.

    This is the paper's memory-bound GEMV path (Fig. 2a, 'Unique KV
    Attention'); the Pallas `decode_attn` kernel is the TPU fast path.
    """
    B, H, D = q.shape
    _, S, KH, _ = k_cache.shape
    G = H // KH
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, KH, G, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(S)[None, :]
    mask = pos < kv_len[:, None]
    if window:
        mask &= pos >= (kv_len[:, None] - window)
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    out = (out / jnp.maximum(l, 1e-37)[..., None]).reshape(B, H, D)
    if return_lse:
        lse = (m + jnp.log(jnp.maximum(l, 1e-37))).reshape(B, H)
        return out.astype(q.dtype), lse
    return out.astype(q.dtype)


def merge_partial_attention(outs, lses):
    """Merge flash-decoding partials: lists of (…, H, D) outs and (…, H) lses.

    Reference semantics for the `lse_merge` Pallas kernel; exactness: the
    merged result equals softmax over the concatenated key sets.
    """
    lse = jnp.stack(lses, axis=0).astype(jnp.float32)        # (P, ..., H)
    o = jnp.stack(outs, axis=0).astype(jnp.float32)          # (P, ..., H, D)
    m = jnp.max(lse, axis=0, keepdims=True)
    w = jnp.exp(lse - m)                                     # (P, ..., H)
    denom = jnp.sum(w, axis=0)
    out = jnp.sum(o * w[..., None], axis=0) / jnp.maximum(denom, 1e-37)[..., None]
    new_lse = jnp.squeeze(m, 0) + jnp.log(jnp.maximum(denom, 1e-37))
    return out.astype(outs[0].dtype), new_lse


# ---------------------------------------------------------------------------
# Attention parameter helpers
# ---------------------------------------------------------------------------

def attn_init(key, d_model: int, num_heads: int, num_kv_heads: int,
              head_dim: int, qkv_bias: bool, dtype) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d_model)
    p = {
        "wq": jax.random.normal(kq, (d_model, num_heads * head_dim), dtype) * s,
        "wk": jax.random.normal(kk, (d_model, num_kv_heads * head_dim), dtype) * s,
        "wv": jax.random.normal(kv, (d_model, num_kv_heads * head_dim), dtype) * s,
        "wo": jax.random.normal(ko, (num_heads * head_dim, d_model), dtype) * s,
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((num_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((num_kv_heads * head_dim,), dtype)
        p["bv"] = jnp.zeros((num_kv_heads * head_dim,), dtype)
    return p


def qkv_project(x: jax.Array, p: dict, num_heads: int, num_kv_heads: int,
                head_dim: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    q = jnp.einsum("...d,dh->...h", x, p["wq"])
    k = jnp.einsum("...d,dh->...h", x, p["wk"])
    v = jnp.einsum("...d,dh->...h", x, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(*x.shape[:-1], num_heads, head_dim)
    k = k.reshape(*x.shape[:-1], num_kv_heads, head_dim)
    v = v.reshape(*x.shape[:-1], num_kv_heads, head_dim)
    return q, k, v


def mlp_init(key, d_model: int, d_ff: int, dtype, gated: bool = True) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(d_ff)
    p = {
        "w_up": jax.random.normal(k2, (d_model, d_ff), dtype) * s_in,
        "w_down": jax.random.normal(k3, (d_ff, d_model), dtype) * s_out,
    }
    if gated:
        p["w_gate"] = jax.random.normal(k1, (d_model, d_ff), dtype) * s_in
    return p
