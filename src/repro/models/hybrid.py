"""RecurrentGemma / Griffin hybrid: RG-LRU recurrent blocks + local (MQA,
sliding-window) attention in a 2:1 pattern [arXiv:2402.19427].

Decode state is O(1) in context length: a ring-buffer window KV per
attention layer and (lru state, conv tail) per recurrent layer — this is why
the hybrid runs ``long_500k`` natively (DESIGN.md §4).

Layers are unrolled in Python (38 layers; HLO stays modest because two of
every three layers are recurrent), unlike the dense stack which scans.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.sharding import lsc

Params = Dict[str, Any]
_LRU_C = 8.0


def _pattern(cfg: ModelConfig):
    cyc = cfg.hybrid.pattern
    return [cyc[i % len(cyc)] for i in range(cfg.num_layers)]


def _lru_width(cfg: ModelConfig) -> int:
    return cfg.hybrid.lru_width or cfg.d_model


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _rec_layer_init(cfg: ModelConfig, key) -> Params:
    d, lw = cfg.d_model, _lru_width(cfg)
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 5)
    s = 1.0 / math.sqrt(d)
    return {
        "ln1": {"scale": jnp.zeros((d,), dtype)},
        "ln2": {"scale": jnp.zeros((d,), dtype)},
        "lru_in": jax.random.normal(ks[0], (d, 2 * lw), dtype) * s,
        "conv_w": jax.random.normal(ks[1], (4, lw), dtype) * 0.1,
        "conv_b": jnp.zeros((lw,), dtype),
        "lru_gate_w": jax.random.normal(ks[2], (lw, 2 * lw), dtype)
            / math.sqrt(lw),
        "lru_gate_b": jnp.zeros((2 * lw,), dtype),
        # Λ init so a^c in (0.9, 0.999) as in Griffin
        "lru_a": jnp.log(jnp.expm1(-jnp.log(
            jnp.linspace(0.9, 0.999, lw).astype(jnp.float32)) / _LRU_C)),
        "lru_out": jax.random.normal(ks[3], (lw, d), dtype) / math.sqrt(lw),
        "mlp": L.mlp_init(ks[4], d, cfg.d_ff, dtype),
    }


def _attn_layer_init(cfg: ModelConfig, key) -> Params:
    ka, km = jax.random.split(key)
    dtype = jnp.dtype(cfg.dtype)
    return {
        "ln1": {"scale": jnp.zeros((cfg.d_model,), dtype)},
        "ln2": {"scale": jnp.zeros((cfg.d_model,), dtype)},
        "attn": L.attn_init(ka, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                            cfg.head_dim, cfg.qkv_bias, dtype),
        "mlp": L.mlp_init(km, cfg.d_model, cfg.d_ff, dtype),
    }


def _cycle(cfg: ModelConfig):
    return cfg.hybrid.pattern


def _layout(cfg: ModelConfig):
    """(n_superblocks, tail_kinds): layers = nsb full cycles + tail."""
    k = len(_cycle(cfg))
    nsb = cfg.num_layers // k
    tail = _cycle(cfg)[: cfg.num_layers % k]
    return nsb, tail


def _tree_stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_params(cfg: ModelConfig, key) -> Params:
    """Layer stack folded as SUPERBLOCKS (one pattern cycle each) so the
    forward scans 12 superblocks instead of unrolling 38 layers — keeps
    HLO size and compile time depth-independent (like the dense stack)."""
    ke, kl = jax.random.split(key)
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(kl, cfg.num_layers)
    nsb, tail = _layout(cfg)
    cyc = _cycle(cfg)
    k = len(cyc)
    sb_rec, sb_attn = [], []
    for s in range(nsb):
        recs = [_rec_layer_init(cfg, keys[s * k + i])
                for i, kind in enumerate(cyc) if kind != "attn"]
        attns = [_attn_layer_init(cfg, keys[s * k + i])
                 for i, kind in enumerate(cyc) if kind == "attn"]
        sb_rec.append(_tree_stack(recs) if recs else {})
        sb_attn.append(_tree_stack(attns) if attns else {})
    tail_blocks = []
    for i, kind in enumerate(tail):
        init = _attn_layer_init if kind == "attn" else _rec_layer_init
        tail_blocks.append(init(cfg, keys[nsb * k + i]))
    return {
        "embed": {"embed": jax.random.normal(
            ke, (cfg.vocab_size, cfg.d_model), dtype) / math.sqrt(cfg.d_model)},
        "super": {"rec": _tree_stack(sb_rec), "attn": _tree_stack(sb_attn)},
        "tail": tail_blocks,
        "final_norm": {"scale": jnp.zeros((cfg.d_model,), dtype)},
    }


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------

def _rglru_gates(x: jax.Array, lp: Params):
    """x: (..., lw) post-conv branch input -> (log_a, gated_in)."""
    gates = jnp.einsum("...l,lg->...g", x, lp["lru_gate_w"]) + lp["lru_gate_b"]
    gates = lsc(gates, "batch", "seq", "state") if gates.ndim == 3 else gates
    r, i = jnp.split(jax.nn.sigmoid(gates.astype(jnp.float32)), 2, axis=-1)
    log_a = -_LRU_C * jax.nn.softplus(lp["lru_a"]) * r      # (..., lw) <= 0
    a2 = jnp.exp(2.0 * log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-12)) * (
        i * x.astype(jnp.float32))
    return log_a, gated


def _rglru_full(x: jax.Array, lp: Params, h0: jax.Array
                ) -> Tuple[jax.Array, jax.Array]:
    """Associative scan over seq. x: (B, S, lw); h0: (B, lw)."""
    log_a, b = _rglru_gates(x, lp)
    a = jnp.exp(log_a)
    # fold h0 into the first step: h_1 = a_1 h0 + b_1
    b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(x.dtype), h[:, -1]


def _rglru_step(x: jax.Array, lp: Params, h: jax.Array
                ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, lw); h: (B, lw)."""
    log_a, b = _rglru_gates(x, lp)
    h_new = jnp.exp(log_a) * h + b
    return h_new.astype(x.dtype), h_new


def _conv_full(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    W = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    return sum(pad[:, i:i + x.shape[1]] * w[i][None, None]
               for i in range(W)) + b[None, None]


def _rec_block_full(cfg, lp, x, h0):
    """x: (B, S, d) -> (out, (conv_tail, h_final))."""
    h = L.rms_norm(x, lp["ln1"]["scale"], cfg.rms_eps)
    xin = jnp.einsum("bsd,dl->bsl", h, lp["lru_in"])
    xa, xb = jnp.split(xin, 2, axis=-1)
    xa = lsc(xa, "batch", "seq", "state")   # lru width over model axis
    xb = lsc(xb, "batch", "seq", "state")
    xa_conv = _conv_full(xa, lp["conv_w"], lp["conv_b"])
    y, h_fin = _rglru_full(xa_conv, lp, h0)
    y = lsc(y, "batch", "seq", "state")
    y = y * jax.nn.gelu(xb)
    x = x + jnp.einsum("bsl,ld->bsd", y, lp["lru_out"])
    h2 = L.rms_norm(x, lp["ln2"]["scale"], cfg.rms_eps)
    x = x + L.geglu_mlp(h2, lp["mlp"])
    conv_tail = xa[:, -(lp["conv_w"].shape[0] - 1):]
    return x, (conv_tail, h_fin)


def _rec_block_step(cfg, lp, x, conv_state, h):
    """x: (B, d)."""
    hn = L.rms_norm(x, lp["ln1"]["scale"], cfg.rms_eps)
    xin = jnp.einsum("bd,dl->bl", hn, lp["lru_in"])
    xa, xb = jnp.split(xin, 2, axis=-1)
    full = jnp.concatenate([conv_state, xa[:, None].astype(conv_state.dtype)],
                           axis=1)
    xa_conv = (jnp.einsum("bwl,wl->bl", full, lp["conv_w"])
               + lp["conv_b"]).astype(xa.dtype)
    conv_state = full[:, 1:]
    y, h = _rglru_step(xa_conv, lp, h)
    y = y * jax.nn.gelu(xb)
    x = x + jnp.einsum("bl,ld->bd", y, lp["lru_out"])
    h2 = L.rms_norm(x, lp["ln2"]["scale"], cfg.rms_eps)
    x = x + L.geglu_mlp(h2, lp["mlp"])
    return x, conv_state, h


# ---------------------------------------------------------------------------
# local attention with ring-buffer window cache
# ---------------------------------------------------------------------------

def _attn_block_full(cfg, lp, x, positions):
    h = L.rms_norm(x, lp["ln1"]["scale"], cfg.rms_eps)
    q, k, v = L.qkv_project(h, lp["attn"], cfg.num_heads, cfg.num_kv_heads,
                            cfg.head_dim)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    q = lsc(q, "batch", "seq", "heads", None)
    o = L.flash_attention(q, k, v, causal=True, window=cfg.hybrid.window,
                          block_k=min(L.DEFAULT_BLOCK_K, cfg.hybrid.window))
    x = x + jnp.einsum("bsh,hd->bsd", o.reshape(*o.shape[:2], -1),
                       lp["attn"]["wo"])
    h2 = L.rms_norm(x, lp["ln2"]["scale"], cfg.rms_eps)
    x = x + L.geglu_mlp(h2, lp["mlp"])
    return x, (k, v)


def _ring_write(rk, rv, rpos, k, v, positions):
    """Write fresh (B, S, KH, D) keys at slots pos % W. Used at prefill."""
    W = rk.shape[1]
    S = k.shape[1]

    def wr(rk_b, rv_b, rpos_b, k_b, v_b, pos_b):
        slots = pos_b % W
        rk_b = rk_b.at[slots].set(k_b.astype(rk_b.dtype))
        rv_b = rv_b.at[slots].set(v_b.astype(rv_b.dtype))
        rpos_b = rpos_b.at[slots].set(pos_b)
        return rk_b, rv_b, rpos_b

    return jax.vmap(wr)(rk, rv, rpos, k, v, positions)


def _ring_attend(q, rk, rv, rpos, q_pos, window):
    """q: (B, H, D); ring caches (B, W, KH, D); rpos: (B, W) abs positions
    (-1 invalid); q_pos: (B,). Returns (B, H, D)."""
    B, H, D = q.shape
    KH = rk.shape[2]
    G = H // KH
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, KH, G, D)
    s = jnp.einsum("bhgd,bwhd->bhgw", qg, rk,
                   preferred_element_type=jnp.float32) * scale
    valid = (rpos >= 0) & (rpos <= q_pos[:, None]) & (
        rpos > q_pos[:, None] - window)
    s = jnp.where(valid[:, None, None], s, L.NEG_INF)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhgw,bwhd->bhgd", p.astype(rv.dtype), rv,
                   preferred_element_type=jnp.float32)
    return (o / jnp.maximum(l, 1e-37)[..., None]).reshape(B, H, D).astype(
        q.dtype)


def _attn_block_step(cfg, lp, x, rk, rv, rpos, q_pos):
    """x: (B, d); q_pos: (B,) absolute position of the new token."""
    h = L.rms_norm(x, lp["ln1"]["scale"], cfg.rms_eps)
    q, k, v = L.qkv_project(h[:, None], lp["attn"], cfg.num_heads,
                            cfg.num_kv_heads, cfg.head_dim)
    q = L.apply_rope(q, q_pos[:, None], cfg.rope_theta)[:, 0]
    k = L.apply_rope(k, q_pos[:, None], cfg.rope_theta)[:, 0]
    v = v[:, 0]
    rk, rv, rpos = _ring_write(rk, rv, rpos, k[:, None], v[:, None],
                               q_pos[:, None])
    o = _ring_attend(q, rk, rv, rpos, q_pos, cfg.hybrid.window)
    x = x + jnp.einsum("bh,hd->bd", o.reshape(x.shape[0], -1),
                       lp["attn"]["wo"])
    h2 = L.rms_norm(x, lp["ln2"]["scale"], cfg.rms_eps)
    x = x + L.geglu_mlp(h2, lp["mlp"])
    return x, rk, rv, rpos


# ---------------------------------------------------------------------------
# model-level API
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16, abstract: bool = False):
    """max_seq is accepted for API parity; hybrid state is O(window)."""
    lw = _lru_width(cfg)
    W = cfg.hybrid.window
    pat = _pattern(cfg)
    n_attn = sum(1 for p in pat if p == "attn")
    n_rec = len(pat) - n_attn
    KH, D = cfg.num_kv_heads, cfg.head_dim
    if abstract:
        mk = jax.ShapeDtypeStruct
        mkposfill = lambda s: jax.ShapeDtypeStruct(s, jnp.int32)
    else:
        mk = lambda s, d: jnp.zeros(s, d)
        mkposfill = lambda s: jnp.full(s, -1, jnp.int32)
    return {
        "ring_k": mk((n_attn, batch, W, KH, D), dtype),
        "ring_v": mk((n_attn, batch, W, KH, D), dtype),
        "ring_pos": mkposfill((n_attn, batch, W)),
        "lru": mk((n_rec, batch, lw), jnp.float32),
        "conv": mk((n_rec, batch, 3, lw), dtype),
        "length": mk((batch,), jnp.int32),
    }


def _sel(tree, i):
    return jax.tree.map(lambda a: a[i], tree)


def forward_hidden(cfg: ModelConfig, params: Params, x: jax.Array,
                   positions: jax.Array, *, remat: bool = True):
    lw = _lru_width(cfg)
    B = x.shape[0]
    h0 = jnp.zeros((B, lw), jnp.float32)
    cyc = _cycle(cfg)
    nsb, tail = _layout(cfg)

    def sb_body(x, xs):
        rec_p, attn_p = xs
        ri = ai = 0
        for kind in cyc:
            if kind == "attn":
                x = _attn_block_full(cfg, _sel(attn_p, ai), x, positions)[0]
                ai += 1
            else:
                x = _rec_block_full(cfg, _sel(rec_p, ri), x, h0)[0]
                ri += 1
        return x, None

    body = sb_body
    if remat:
        body = jax.checkpoint(
            sb_body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, (params["super"]["rec"],
                                  params["super"]["attn"]))
    for lp, kind in zip(params["tail"], tail):
        if kind == "attn":
            x = _attn_block_full(cfg, lp, x, positions)[0]
        else:
            x = _rec_block_full(cfg, lp, x, h0)[0]
    x = L.rms_norm(x, params["final_norm"]["scale"], cfg.rms_eps)
    return x, jnp.zeros((), jnp.float32)


def train_loss(cfg: ModelConfig, params: Params, batch, *, remat=True):
    from repro.models.dense import lm_loss
    tokens = batch["tokens"]
    x = params["embed"]["embed"][tokens]
    positions = jnp.arange(tokens.shape[1])
    hidden, _ = forward_hidden(cfg, params, x, positions, remat=remat)
    loss = lm_loss(cfg, params, hidden, batch["targets"], batch["mask"])
    return loss, {"ce_loss": loss, "moe_aux": jnp.zeros((), jnp.float32)}


def _counts(cfg: ModelConfig):
    cyc = _cycle(cfg)
    nsb, tail = _layout(cfg)
    a_c = sum(1 for p in cyc if p == "attn")
    r_c = len(cyc) - a_c
    tail_a = sum(1 for p in tail if p == "attn")
    tail_r = len(tail) - tail_a
    return nsb, a_c, r_c, tail_a, tail_r


def _split_sb(arr, nsb, per, tail_n):
    """(n_total, ...) -> ((nsb, per, ...), (tail_n, ...))."""
    head = arr[: nsb * per].reshape(nsb, per, *arr.shape[1:])
    return head, arr[nsb * per:]


def prefill(cfg: ModelConfig, params: Params, tokens: jax.Array, cache,
            store=None, frontend_embeds=None, start_pos: int = 0):
    x = params["embed"]["embed"][tokens]
    B, S, _ = x.shape
    positions = start_pos + jnp.arange(S)
    lw = _lru_width(cfg)
    W = cfg.hybrid.window
    h0 = jnp.zeros((B, lw), jnp.float32)
    cyc = _cycle(cfg)
    nsb, a_c, r_c, tail_a, tail_r = _counts(cfg)
    abs_pos = jnp.broadcast_to(positions[None], (B, S))
    n = min(W, S)

    def prefill_attn(lp, x, rk0, rv0, rp0):
        x, (k, v) = _attn_block_full(cfg, lp, x, positions)
        rk, rv, rpos = _ring_write(rk0, rv0, rp0, k[:, -n:], v[:, -n:],
                                   abs_pos[:, -n:])
        return x, (rk, rv, rpos)

    def prefill_rec(lp, x):
        x, (conv_tail, h_fin) = _rec_block_full(cfg, lp, x, h0)
        ct = conv_tail
        if ct.shape[1] < 3:   # short prefix: left-pad with zeros
            ct = jnp.pad(ct, ((0, 0), (3 - ct.shape[1], 0), (0, 0)))
        return x, (ct.astype(cache["conv"].dtype), h_fin)

    rk_h, rk_t = _split_sb(cache["ring_k"], nsb, a_c, tail_a)
    rv_h, rv_t = _split_sb(cache["ring_v"], nsb, a_c, tail_a)
    rp_h, rp_t = _split_sb(cache["ring_pos"], nsb, a_c, tail_a)

    def sb_body(x, xs):
        rec_p, attn_p, rk0, rv0, rp0 = xs
        ri = ai = 0
        rks, rvs, rps, convs, lrus = [], [], [], [], []
        for kind in cyc:
            if kind == "attn":
                x, (rk, rv, rp) = prefill_attn(_sel(attn_p, ai), x,
                                               rk0[ai], rv0[ai], rp0[ai])
                rks.append(rk); rvs.append(rv); rps.append(rp)
                ai += 1
            else:
                x, (ct, h) = prefill_rec(_sel(rec_p, ri), x)
                convs.append(ct); lrus.append(h)
                ri += 1
        return x, (jnp.stack(rks), jnp.stack(rvs), jnp.stack(rps),
                   jnp.stack(convs), jnp.stack(lrus))

    x, (rk_n, rv_n, rp_n, conv_n, lru_n) = jax.lax.scan(
        sb_body, x, (params["super"]["rec"], params["super"]["attn"],
                     rk_h, rv_h, rp_h))

    rk_all = [rk_n.reshape(-1, *rk_n.shape[2:])]
    rv_all = [rv_n.reshape(-1, *rv_n.shape[2:])]
    rp_all = [rp_n.reshape(-1, *rp_n.shape[2:])]
    conv_all = [conv_n.reshape(-1, *conv_n.shape[2:])]
    lru_all = [lru_n.reshape(-1, *lru_n.shape[2:])]
    nsb_, tail = _layout(cfg)
    ti_a = 0
    for i, (lp, kind) in enumerate(zip(params["tail"], tail)):
        if kind == "attn":
            x, (rk, rv, rp) = prefill_attn(lp, x, rk_t[ti_a], rv_t[ti_a],
                                           rp_t[ti_a])
            rk_all.append(rk[None]); rv_all.append(rv[None])
            rp_all.append(rp[None])
            ti_a += 1
        else:
            x, (ct, h) = prefill_rec(lp, x)
            conv_all.append(ct[None]); lru_all.append(h[None])
    x = L.rms_norm(x, params["final_norm"]["scale"], cfg.rms_eps)
    logits = jnp.einsum("bd,vd->bv", x[:, -1], params["embed"]["embed"],
                        preferred_element_type=jnp.float32)
    new_cache = {
        "ring_k": jnp.concatenate(rk_all),
        "ring_v": jnp.concatenate(rv_all),
        "ring_pos": jnp.concatenate(rp_all),
        "lru": jnp.concatenate(lru_all),
        "conv": jnp.concatenate(conv_all),
        "length": jnp.full((B,), start_pos + S, jnp.int32),
    }
    return logits, new_cache


def decode_step(cfg: ModelConfig, params: Params, tokens: jax.Array, cache,
                store=None, positions=None, kernel=None):
    x = params["embed"]["embed"][tokens]
    q_pos = cache["length"] if positions is None else positions
    cyc = _cycle(cfg)
    nsb, a_c, r_c, tail_a, tail_r = _counts(cfg)

    rk_h, rk_t = _split_sb(cache["ring_k"], nsb, a_c, tail_a)
    rv_h, rv_t = _split_sb(cache["ring_v"], nsb, a_c, tail_a)
    rp_h, rp_t = _split_sb(cache["ring_pos"], nsb, a_c, tail_a)
    cv_h, cv_t = _split_sb(cache["conv"], nsb, r_c, tail_r)
    lr_h, lr_t = _split_sb(cache["lru"], nsb, r_c, tail_r)

    def sb_body(x, xs):
        rec_p, attn_p, rk0, rv0, rp0, cv0, lr0 = xs
        ri = ai = 0
        rks, rvs, rps, convs, lrus = [], [], [], [], []
        for kind in cyc:
            if kind == "attn":
                x, rk, rv, rp = _attn_block_step(
                    cfg, _sel(attn_p, ai), x, rk0[ai], rv0[ai], rp0[ai],
                    q_pos)
                rks.append(rk); rvs.append(rv); rps.append(rp)
                ai += 1
            else:
                x, cs, h = _rec_block_step(cfg, _sel(rec_p, ri), x,
                                           cv0[ri], lr0[ri])
                convs.append(cs); lrus.append(h)
                ri += 1
        return x, (jnp.stack(rks), jnp.stack(rvs), jnp.stack(rps),
                   jnp.stack(convs), jnp.stack(lrus))

    x, (rk_n, rv_n, rp_n, conv_n, lru_n) = jax.lax.scan(
        sb_body, x, (params["super"]["rec"], params["super"]["attn"],
                     rk_h, rv_h, rp_h, cv_h, lr_h))

    rk_all = [rk_n.reshape(-1, *rk_n.shape[2:])]
    rv_all = [rv_n.reshape(-1, *rv_n.shape[2:])]
    rp_all = [rp_n.reshape(-1, *rp_n.shape[2:])]
    conv_all = [conv_n.reshape(-1, *conv_n.shape[2:])]
    lru_all = [lru_n.reshape(-1, *lru_n.shape[2:])]
    _, tail = _layout(cfg)
    ti_a = ti_r = 0
    for lp, kind in zip(params["tail"], tail):
        if kind == "attn":
            x, rk, rv, rp = _attn_block_step(
                cfg, lp, x, rk_t[ti_a], rv_t[ti_a], rp_t[ti_a], q_pos)
            rk_all.append(rk[None]); rv_all.append(rv[None])
            rp_all.append(rp[None])
            ti_a += 1
        else:
            x, cs, h = _rec_block_step(cfg, lp, x, cv_t[ti_r], lr_t[ti_r])
            conv_all.append(cs[None]); lru_all.append(h[None])
            ti_r += 1
    x = L.rms_norm(x, params["final_norm"]["scale"], cfg.rms_eps)
    logits = jnp.einsum("bd,vd->bv", x, params["embed"]["embed"],
                        preferred_element_type=jnp.float32)
    new_cache = {
        "ring_k": jnp.concatenate(rk_all),
        "ring_v": jnp.concatenate(rv_all),
        "ring_pos": jnp.concatenate(rp_all),
        "lru": jnp.concatenate(lru_all),
        "conv": jnp.concatenate(conv_all),
        "length": cache["length"] + 1,
    }
    return logits, new_cache
