"""Model facade: dispatches the family-specific implementations behind one
API used by training, serving, launch, and tests.

    model = build_model(cfg)
    params = model.init(key)
    loss, metrics = model.train_loss(params, batch)
    cache = model.init_cache(batch_size, max_seq)
    logits, cache = model.prefill(params, tokens, cache, store=...)
    logits, cache = model.decode_step(params, tokens, cache, store=...)
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import (AUDIO, DENSE, HYBRID, MOE, SSM, VLM,
                                ModelConfig)
from repro.kvcache.cache import abstract_kv_cache, init_kv_cache
from repro.kvcache.paged import init_paged_kv_cache


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        if cfg.family in (DENSE, VLM, MOE):
            from repro.models import dense as impl
        elif cfg.family == SSM:
            from repro.models import ssm as impl
        elif cfg.family == HYBRID:
            from repro.models import hybrid as impl
        elif cfg.family == AUDIO:
            from repro.models import encdec as impl
        else:
            raise ValueError(cfg.family)
        self._impl = impl

    # ------------------------------------------------------------------
    def init(self, key):
        return self._impl.init_params(self.cfg, key)

    def abstract_params(self, key=None):
        key = key if key is not None else jax.random.PRNGKey(0)
        return jax.eval_shape(lambda k: self._impl.init_params(self.cfg, k),
                              key)

    def train_loss(self, params, batch: Dict[str, Any], *, remat: bool = True):
        return self._impl.train_loss(self.cfg, params, batch, remat=remat)

    # ------------------------------------------------------------------
    def init_cache(self, batch: int, max_seq: int, dtype=jnp.bfloat16,
                   abstract: bool = False):
        cfg = self.cfg
        if cfg.family in (DENSE, VLM, MOE):
            fn = abstract_kv_cache if abstract else init_kv_cache
            return fn(cfg.num_layers, batch, max_seq, cfg.num_kv_heads,
                      cfg.head_dim, dtype)
        return self._impl.init_cache(cfg, batch, max_seq, dtype,
                                     abstract=abstract)

    def prefill(self, params, tokens, cache, store=None,
                frontend_embeds=None, start_pos: int = 0, true_len=None):
        # true_len: real prompt length for bucket-padded serving prefill
        # (dense-family only — the engine's zero-copy hot path)
        kw = {} if true_len is None else {"true_len": true_len}
        if self.cfg.family in (VLM, AUDIO):
            return self._impl.prefill(self.cfg, params, tokens, cache,
                                      store=store,
                                      frontend_embeds=frontend_embeds,
                                      start_pos=start_pos, **kw)
        return self._impl.prefill(self.cfg, params, tokens, cache,
                                  store=store, start_pos=start_pos, **kw)

    def decode_step(self, params, tokens, cache, store=None, positions=None,
                    kernel: Optional[str] = None):
        return self._impl.decode_step(self.cfg, params, tokens, cache,
                                      store=store, positions=positions,
                                      kernel=kernel)

    # -- paged KV layout (dense-family only) ---------------------------
    def _require_paged(self, what: str):
        if self.cfg.family not in (DENSE, VLM, MOE):
            raise NotImplementedError(
                f"{what} requires the paged KV layout, which only the "
                f"dense-family caches support (family={self.cfg.family!r}; "
                "use kv_layout='slotted')")

    def init_paged_cache(self, num_blocks: int, block_size: int,
                         dtype=jnp.bfloat16):
        self._require_paged("init_paged_cache")
        cfg = self.cfg
        return init_paged_kv_cache(cfg.num_layers, num_blocks, block_size,
                                   cfg.num_kv_heads, cfg.head_dim, dtype)

    def decode_step_paged(self, params, tokens, pool, table, lengths,
                          offsets, store=None,
                          kernel: Optional[str] = None):
        self._require_paged("decode_step_paged")
        return self._impl.decode_step_paged(self.cfg, params, tokens, pool,
                                            table, lengths, offsets,
                                            store=store, kernel=kernel)

    def prefill_chunk(self, params, tokens, cache, store=None,
                      start_pos=0, chunk_len=None):
        self._require_paged("prefill_chunk")
        return self._impl.prefill_chunk(self.cfg, params, tokens, cache,
                                        store=store, start_pos=start_pos,
                                        chunk_len=chunk_len)


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
