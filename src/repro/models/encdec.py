"""Whisper-style encoder-decoder (audio family) [arXiv:2212.04356].

The mel-spectrogram + conv1d frontend is a STUB per the assignment:
``input_specs`` provides precomputed frame embeddings (B, frames, d_model).
Positions use sinusoidal embeddings computed on the fly (deviation from
Whisper's learned decoder positions, which cap at 448 — the assigned
decode_32k shape needs 32K positions; recorded in DESIGN.md).

MoSKA applicability (partial): when many requests decode against the same
audio corpus, the *cross-attention* KV is shared; ``store`` routes the
decoder's cross-attention through the batched Shared KV Attention path
instead of per-request cross KV.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import moska_attention as MA
from repro.core import router as router_lib
from repro.kvcache.cache import KVCache, append_token, write_prefix
from repro.models import layers as L

Params = Dict[str, Any]


def sinusoid_pos(positions: jax.Array, d: int) -> jax.Array:
    half = d // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half) / max(half - 1, 1))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _ln_init(d, dtype):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def _enc_layer_init(cfg: ModelConfig, key) -> Params:
    ka, km = jax.random.split(key)
    dtype = jnp.dtype(cfg.dtype)
    return {
        "ln1": _ln_init(cfg.d_model, dtype),
        "ln2": _ln_init(cfg.d_model, dtype),
        "attn": L.attn_init(ka, cfg.d_model, cfg.num_heads, cfg.num_heads,
                            cfg.head_dim, cfg.qkv_bias, dtype),
        "mlp": L.mlp_init(km, cfg.d_model, cfg.d_ff, dtype, gated=False),
    }


def _dec_layer_init(cfg: ModelConfig, key) -> Params:
    ka, kc, km = jax.random.split(key, 3)
    dtype = jnp.dtype(cfg.dtype)
    return {
        "ln1": _ln_init(cfg.d_model, dtype),
        "ln_x": _ln_init(cfg.d_model, dtype),
        "ln2": _ln_init(cfg.d_model, dtype),
        "attn": L.attn_init(ka, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                            cfg.head_dim, cfg.qkv_bias, dtype),
        "xattn": L.attn_init(kc, cfg.d_model, cfg.num_heads, cfg.num_heads,
                             cfg.head_dim, cfg.qkv_bias, dtype),
        "mlp": L.mlp_init(km, cfg.d_model, cfg.d_ff, dtype, gated=False),
    }


def init_params(cfg: ModelConfig, key) -> Params:
    ke, ken, kd = jax.random.split(key, 3)
    dtype = jnp.dtype(cfg.dtype)
    enc_keys = jax.random.split(ken, cfg.encoder.num_layers)
    dec_keys = jax.random.split(kd, cfg.num_layers)
    return {
        "embed": {"embed": jax.random.normal(
            ke, (cfg.vocab_size, cfg.d_model), dtype) / math.sqrt(cfg.d_model)},
        "enc_layers": jax.vmap(partial(_enc_layer_init, cfg))(enc_keys),
        "enc_norm": _ln_init(cfg.d_model, dtype),
        "dec_layers": jax.vmap(partial(_dec_layer_init, cfg))(dec_keys),
        "final_norm": _ln_init(cfg.d_model, dtype),
    }


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------

def encode(cfg: ModelConfig, params: Params,
           frames: jax.Array) -> jax.Array:
    """frames: (B, F, d) stub frontend embeddings -> (B, F, d)."""
    B, F, d = frames.shape
    x = frames + sinusoid_pos(jnp.arange(F), d)[None].astype(frames.dtype)

    def body(x, lp):
        h = L.layer_norm(x, lp["ln1"]["scale"], lp["ln1"]["bias"])
        q, k, v = L.qkv_project(h, lp["attn"], cfg.num_heads, cfg.num_heads,
                                cfg.head_dim)
        o = L.flash_attention(q, k, v, causal=False)
        x = x + jnp.einsum("bsh,hd->bsd", o.reshape(B, F, -1),
                           lp["attn"]["wo"])
        h2 = L.layer_norm(x, lp["ln2"]["scale"], lp["ln2"]["bias"])
        x = x + L.gelu_mlp(h2, lp["mlp"])
        return x, None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return L.layer_norm(x, params["enc_norm"]["scale"],
                        params["enc_norm"]["bias"])


# ---------------------------------------------------------------------------
# decoder
# ---------------------------------------------------------------------------

def _cross_kv(cfg: ModelConfig, lp: Params, enc_out: jax.Array):
    _, k, v = L.qkv_project(enc_out, lp["xattn"], cfg.num_heads,
                            cfg.num_heads, cfg.head_dim)
    return k, v


def _dec_layer_full(cfg, lp, x, positions, xk, xv):
    """Teacher-forced decoder layer. x: (B, S, d); xk/xv: (B, F, H, D)."""
    B, S, _ = x.shape
    h = L.layer_norm(x, lp["ln1"]["scale"], lp["ln1"]["bias"])
    q, k, v = L.qkv_project(h, lp["attn"], cfg.num_heads, cfg.num_kv_heads,
                            cfg.head_dim)
    o = L.flash_attention(q, k, v, causal=True)
    x = x + jnp.einsum("bsh,hd->bsd", o.reshape(B, S, -1), lp["attn"]["wo"])
    hx = L.layer_norm(x, lp["ln_x"]["scale"], lp["ln_x"]["bias"])
    qx, _, _ = L.qkv_project(hx, lp["xattn"], cfg.num_heads, cfg.num_heads,
                             cfg.head_dim)
    ox = L.flash_attention(qx, xk, xv, causal=False)
    x = x + jnp.einsum("bsh,hd->bsd", ox.reshape(B, S, -1), lp["xattn"]["wo"])
    h2 = L.layer_norm(x, lp["ln2"]["scale"], lp["ln2"]["bias"])
    x = x + L.gelu_mlp(h2, lp["mlp"])
    return x


def forward_teacher_forced(cfg, params, frames, tokens, *, remat=True):
    enc_out = encode(cfg, params, frames)
    B, S = tokens.shape
    d = cfg.d_model
    x = params["embed"]["embed"][tokens]
    positions = jnp.arange(S)
    x = x + sinusoid_pos(positions, d)[None].astype(x.dtype)

    def body(x, lp):
        xk, xv = _cross_kv(cfg, lp, enc_out)
        fn = partial(_dec_layer_full, cfg)
        if remat:
            fn = jax.checkpoint(
                fn, policy=jax.checkpoint_policies.nothing_saveable)
        return fn(lp, x, positions, xk, xv), None

    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    return L.layer_norm(x, params["final_norm"]["scale"],
                        params["final_norm"]["bias"])


def train_loss(cfg, params, batch, *, remat=True):
    from repro.models.dense import lm_loss
    hidden = forward_teacher_forced(cfg, params, batch["frontend_embeds"],
                                    batch["tokens"], remat=remat)
    loss = lm_loss(cfg, params, hidden, batch["targets"], batch["mask"])
    return loss, {"ce_loss": loss, "moe_aux": jnp.zeros((), jnp.float32)}


# ---------------------------------------------------------------------------
# serving (prefill/decode with self-cache + precomputed cross KV)
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16, abstract: bool = False):
    Ld = cfg.num_layers
    F = cfg.encoder.frontend_seq
    H, D = cfg.num_heads, cfg.head_dim
    KH = cfg.num_kv_heads
    mk = jax.ShapeDtypeStruct if abstract else (lambda s, d: jnp.zeros(s, d))
    return {
        "self_k": mk((Ld, batch, max_seq, KH, D), dtype),
        "self_v": mk((Ld, batch, max_seq, KH, D), dtype),
        "cross_k": mk((Ld, batch, F, H, D), dtype),
        "cross_v": mk((Ld, batch, F, H, D), dtype),
        "length": mk((batch,), jnp.int32),
    }


def prefill(cfg, params, tokens, cache, store=None, frontend_embeds=None,
            start_pos: int = 0):
    """Encode frames, precompute cross KV, run decoder prefix."""
    enc_out = encode(cfg, params, frontend_embeds)
    B, S = tokens.shape
    d = cfg.d_model
    x = params["embed"]["embed"][tokens]
    positions = start_pos + jnp.arange(S)
    x = x + sinusoid_pos(positions, d)[None].astype(x.dtype)

    def body(x, xs):
        lp, kc, vc = xs
        xk, xv = _cross_kv(cfg, lp, enc_out)
        h = L.layer_norm(x, lp["ln1"]["scale"], lp["ln1"]["bias"])
        q, k, v = L.qkv_project(h, lp["attn"], cfg.num_heads,
                                cfg.num_kv_heads, cfg.head_dim)
        kc, vc = write_prefix(kc, vc, k, v)
        o = L.flash_attention(q, k, v, causal=True)
        x = x + jnp.einsum("bsh,hd->bsd", o.reshape(B, S, -1),
                           lp["attn"]["wo"])
        hx = L.layer_norm(x, lp["ln_x"]["scale"], lp["ln_x"]["bias"])
        qx, _, _ = L.qkv_project(hx, lp["xattn"], cfg.num_heads,
                                 cfg.num_heads, cfg.head_dim)
        ox = L.flash_attention(qx, xk, xv, causal=False)
        x = x + jnp.einsum("bsh,hd->bsd", ox.reshape(B, S, -1),
                           lp["xattn"]["wo"])
        h2 = L.layer_norm(x, lp["ln2"]["scale"], lp["ln2"]["bias"])
        x = x + L.gelu_mlp(h2, lp["mlp"])
        return x, (kc, vc, xk, xv)

    x, (k_new, v_new, xk_all, xv_all) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["self_k"], cache["self_v"]))
    x = L.layer_norm(x, params["final_norm"]["scale"],
                     params["final_norm"]["bias"])
    logits = jnp.einsum("bd,vd->bv", x[:, -1], params["embed"]["embed"],
                        preferred_element_type=jnp.float32)
    new_cache = {"self_k": k_new, "self_v": v_new,
                 "cross_k": xk_all.astype(cache["cross_k"].dtype),
                 "cross_v": xv_all.astype(cache["cross_v"].dtype),
                 "length": jnp.full((B,), S, jnp.int32)}
    return logits, new_cache


def decode_step(cfg, params, tokens, cache, store=None, positions=None,
                kernel=None):
    """One decode token. ``store``: optional SharedKVStore of cross-KV
    chunks (shared audio corpus) routed via MoSKA instead of per-request
    cross caches."""
    B = tokens.shape[0]
    d = cfg.d_model
    if positions is None:
        positions = cache["length"]
    x = params["embed"]["embed"][tokens]
    x = x + sinusoid_pos(positions, d).astype(x.dtype)

    shared = None
    if store is not None and cfg.moska.enabled:
        shared = (store.k, store.v, store.emb)

    def body(x, xs):
        if shared is not None:
            lp, kc, vc, sk, sv, semb = xs
        else:
            lp, kc, vc, xk, xv = xs
        h = L.layer_norm(x, lp["ln1"]["scale"], lp["ln1"]["bias"])
        q, k, v = L.qkv_project(h[:, None], lp["attn"], cfg.num_heads,
                                cfg.num_kv_heads, cfg.head_dim)
        q, k, v = q[:, 0], k[:, 0], v[:, 0]
        kc, vc = append_token(kc, vc, k, v, cache["length"])
        o = L.decode_attention(q, kc, vc, cache["length"] + 1)
        x = x + jnp.einsum("bh,hd->bd", o.reshape(B, -1), lp["attn"]["wo"])
        hx = L.layer_norm(x, lp["ln_x"]["scale"], lp["ln_x"]["bias"])
        qx, _, _ = L.qkv_project(hx[:, None], lp["xattn"], cfg.num_heads,
                                 cfg.num_heads, cfg.head_dim)
        qx = qx[:, 0]
        if shared is not None:
            routing = router_lib.route(qx, semb, cfg.moska.top_k_chunks)
            from repro.core import shared_attention as sa
            part = sa.shared_attention_batched(qx[:, None], sk, sv, routing)
            ox = part.out[:, 0]
        else:
            F = xk.shape[1]
            ox = L.decode_attention(qx, xk, xv,
                                    jnp.full((B,), F, jnp.int32))
        x = x + jnp.einsum("bh,hd->bd", ox.reshape(B, -1), lp["xattn"]["wo"])
        h2 = L.layer_norm(x, lp["ln2"]["scale"], lp["ln2"]["bias"])
        x = x + L.gelu_mlp(h2, lp["mlp"])
        return x, (kc, vc)

    if shared is not None:
        xs = (params["dec_layers"], cache["self_k"], cache["self_v"],
              *shared)
    else:
        xs = (params["dec_layers"], cache["self_k"], cache["self_v"],
              cache["cross_k"], cache["cross_v"])
    x, (k_new, v_new) = jax.lax.scan(body, x, xs)
    x = L.layer_norm(x, params["final_norm"]["scale"],
                     params["final_norm"]["bias"])
    logits = jnp.einsum("bd,vd->bv", x, params["embed"]["embed"],
                        preferred_element_type=jnp.float32)
    new_cache = dict(cache)
    new_cache.update({"self_k": k_new, "self_v": v_new,
                      "length": cache["length"] + 1})
    return logits, new_cache
