"""Dense / GQA decoder — covers the dense, vlm, and moe families.

Pre-norm transformer with RoPE, GQA attention, SwiGLU FFN (or
capacity-dispatch MoE), layer stack folded with ``jax.lax.scan`` so HLO size
is depth-independent (mandatory for the 88-layer Mistral-Large dry-run).

MoSKA integration: at prefill/decode, when a ``SharedKVStore`` is attached,
each layer routes its queries over the layer's shared chunks and merges the
batched shared partial with the unique partial (core/moska_attention.py).

VLM (internvl2): the stub vision frontend delivers patch embeddings
(B, P, d_model) which are prepended to the token embeddings; loss masks the
patch positions. No cross-attention (InternVL2 is decoder-inline).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import moska_attention as MA
from repro.core import router as router_lib
from repro.core import shared_attention as sa
from repro.core.shared_kv import SharedKVStore
from repro.kvcache.cache import KVCache, append_token, write_prefix
from repro.kvcache.paged import PagedKVCache, append_layer, gather_layer
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.sharding import lsc

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _layer_init(cfg: ModelConfig, key) -> Params:
    ka, km, kd = jax.random.split(key, 3)
    dtype = jnp.dtype(cfg.dtype)
    p: Params = {
        "ln1": {"scale": jnp.zeros((cfg.d_model,), dtype)},
        "ln2": {"scale": jnp.zeros((cfg.d_model,), dtype)},
        "attn": L.attn_init(ka, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                            cfg.head_dim, cfg.qkv_bias, dtype),
    }
    if cfg.moe.enabled:
        p["moe"] = moe_lib.moe_init(km, cfg.d_model, cfg.d_ff, cfg.moe, dtype)
        if cfg.moe.dense_residual:
            p["mlp"] = L.mlp_init(kd, cfg.d_model, cfg.d_ff, dtype)
    else:
        p["mlp"] = L.mlp_init(km, cfg.d_model, cfg.d_ff, dtype)
    return p


def init_params(cfg: ModelConfig, key) -> Params:
    ke, kl, ku = jax.random.split(key, 3)
    dtype = jnp.dtype(cfg.dtype)
    layer_keys = jax.random.split(kl, cfg.num_layers)
    layers = jax.vmap(partial(_layer_init, cfg))(layer_keys)
    params: Params = {
        "embed": {"embed": jax.random.normal(
            ke, (cfg.vocab_size, cfg.d_model), dtype) / math.sqrt(cfg.d_model)},
        "layers": layers,
        "final_norm": {"scale": jnp.zeros((cfg.d_model,), dtype)},
    }
    if not cfg.tie_embeddings:
        params["unembed"] = {"unembed": jax.random.normal(
            ku, (cfg.vocab_size, cfg.d_model), dtype) / math.sqrt(cfg.d_model)}
    return params


def unembed_matrix(cfg: ModelConfig, params: Params) -> jax.Array:
    if cfg.tie_embeddings or "unembed" not in params:
        return params["embed"]["embed"]
    return params["unembed"]["unembed"]


# ---------------------------------------------------------------------------
# layer bodies
# ---------------------------------------------------------------------------

def _ffn(cfg: ModelConfig, lp: Params, x: jax.Array
         ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, moe_aux)."""
    B, S, d = x.shape
    if cfg.moe.enabled:
        y, aux = moe_lib.moe_ffn(x.reshape(B * S, d), lp["moe"], cfg.moe)
        y = y.reshape(B, S, d)
        if cfg.moe.dense_residual:
            y = y + L.swiglu_mlp(x, lp["mlp"])
        return y, aux
    return L.swiglu_mlp(x, lp["mlp"]), jnp.zeros((), jnp.float32)


def _attn_out_proj(o: jax.Array, lp: Params) -> jax.Array:
    """o: (B, S, H, D) or (B, H, D) -> project back to d_model."""
    flat = o.reshape(*o.shape[:-2], -1)
    return jnp.einsum("...h,hd->...d", flat, lp["attn"]["wo"])


def _layer_train(cfg: ModelConfig, x: jax.Array, lp: Params,
                 positions: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence causal layer (train / no-cache forward).

    x: (B, S, d); positions: (S,) or (B, S). Returns (x_out, moe_aux).
    """
    h = L.rms_norm(x, lp["ln1"]["scale"], cfg.rms_eps)
    q, k, v = L.qkv_project(h, lp["attn"], cfg.num_heads, cfg.num_kv_heads,
                            cfg.head_dim)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    q = lsc(q, "batch", "seq", "heads", None)
    k = lsc(k, "batch", "seq", "kv_heads", None)
    v = lsc(v, "batch", "seq", "kv_heads", None)
    o = L.flash_attention(q, k, v, causal=True, window=cfg.attn_window,
                          block_k=cfg.attn_block_k)
    x = lsc(x + _attn_out_proj(o, lp), "batch", "seq_res", None)
    h2 = L.rms_norm(x, lp["ln2"]["scale"], cfg.rms_eps)
    y, aux = _ffn(cfg, lp, h2)
    x = lsc(x + y, "batch", "seq_res", None)
    return x, aux


def _layer_prefill(cfg: ModelConfig, x: jax.Array, lp: Params,
                   positions: jax.Array,
                   kc: jax.Array, vc: jax.Array,
                   shared: Optional[Tuple[jax.Array, jax.Array, jax.Array]],
                   q_offset: jax.Array,
                   true_len: Optional[jax.Array] = None,
                   layer_idx: Optional[jax.Array] = None
                   ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Prefill layer: causal attention + cache write + optional MoSKA path.

    ``true_len`` (traced scalar ok): the real prompt length when the
    sequence is right-padded to a prefill bucket. Pad queries are excluded
    from router pooling so routing (and hence every real row's output)
    matches the exact-length program; pad rows themselves produce garbage
    that the caller discards.

    Returns (x_out, new_k_layer, new_v_layer, aux).
    """
    h = L.rms_norm(x, lp["ln1"]["scale"], cfg.rms_eps)
    q, k, v = L.qkv_project(h, lp["attn"], cfg.num_heads, cfg.num_kv_heads,
                            cfg.head_dim)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    q = lsc(q, "batch", "seq", "heads", None)
    kc, vc = write_prefix(kc, vc, k, v)

    ctx = None
    if shared is not None and cfg.moska.enabled:
        sk, sv, semb = _shared_layer(shared, x.dtype)
        B, S, H, D = q.shape
        rb = min(128, S)
        nb = S // rb
        if true_len is None:
            pooled = jnp.mean(q.reshape(B * nb, rb, H, D), axis=1)
        else:
            valid = (jnp.arange(S) < true_len).astype(q.dtype)     # (S,)
            qs = (q * valid[None, :, None, None]).reshape(B, nb, rb, H, D)
            cnt = jnp.maximum(valid.reshape(nb, rb).sum(axis=1), 1.0)
            pooled = (jnp.sum(qs, axis=2) /
                      cnt[None, :, None, None]).reshape(B * nb, H, D)
        routing = router_lib.route(pooled, semb, cfg.moska.top_k_chunks)
        ctx = MA.MoskaLayerContext(sk, sv, routing)
        o = MA.moska_prefill_attention(
            q, k, v, ctx, cfg.moska, q_offset=q_offset,
            window=cfg.attn_window, route_block=rb, layer_idx=layer_idx)
    else:
        o = L.flash_attention(q, k, v, causal=True, q_offset=q_offset,
                              kv_offset=q_offset, window=cfg.attn_window)
    x = x + lsc(_attn_out_proj(o, lp), "batch", "seq", None)
    h2 = L.rms_norm(x, lp["ln2"]["scale"], cfg.rms_eps)
    y, aux = _ffn(cfg, lp, h2)
    x = x + lsc(y, "batch", "seq", None)
    return x, kc, vc, aux


def _layer_decode(cfg: ModelConfig, x: jax.Array, lp: Params,
                  positions: jax.Array,
                  kc: jax.Array, vc: jax.Array, lengths: jax.Array,
                  shared: Optional[Tuple[jax.Array, jax.Array, jax.Array]],
                  kernel: Optional[str] = None,
                  layer_idx: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Decode layer: one token per request.

    x: (B, d); positions: (B,) absolute position of the new token.
    Returns (x_out, new_k_layer, new_v_layer).
    """
    B, d = x.shape
    h = L.rms_norm(x, lp["ln1"]["scale"], cfg.rms_eps)
    q, k, v = L.qkv_project(h[:, None], lp["attn"], cfg.num_heads,
                            cfg.num_kv_heads, cfg.head_dim)
    q = L.apply_rope(q, positions[:, None], cfg.rope_theta)[:, 0]  # (B,H,D)
    k = L.apply_rope(k, positions[:, None], cfg.rope_theta)[:, 0]
    v = v[:, 0]
    q = lsc(q, "batch", "heads", None)
    kc, vc = append_token(kc, vc, k, v, lengths)
    new_len = lengths + 1

    ctx = None
    if shared is not None and cfg.moska.enabled:
        sk, sv, semb = _shared_layer(shared, x.dtype)
        routing = router_lib.route(q, semb, cfg.moska.top_k_chunks)
        ctx = MA.MoskaLayerContext(sk, sv, routing)
    o = MA.moska_decode_attention(q, kc, vc, new_len, ctx, cfg.moska,
                                  window=cfg.attn_window, kernel=kernel,
                                  layer_idx=layer_idx)
    x = x + _attn_out_proj(o, lp)
    h2 = L.rms_norm(x, lp["ln2"]["scale"], cfg.rms_eps)
    y, _ = _ffn(cfg, lp, h2[:, None])
    x = x + y[:, 0]
    return x, kc, vc


def _layer_decode_paged(cfg: ModelConfig, x: jax.Array, lp: Params,
                        positions: jax.Array,
                        kp: jax.Array, vp: jax.Array,
                        table: jax.Array, lengths: jax.Array,
                        shared: Optional[Tuple[jax.Array, jax.Array,
                                               jax.Array]],
                        kernel: Optional[str] = None,
                        layer_idx: Optional[jax.Array] = None
                        ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Paged decode layer: identical math to ``_layer_decode`` but the
    unique KV lives in a block pool.

    kp/vp: (N, bs, KH, D) one layer's physical pages; table: (B, M) block
    tables; lengths: (B,). The new token is scattered into its page, then
    the tables gather a contiguous (B, M*bs, KH, D) view and the *same*
    mixture attention runs on it — when ``M*bs == max_seq`` the attention
    program is shape-identical to the slotted one and (because masked
    positions get exactly-zero softmax weight) the outputs are bitwise
    equal for live slots.
    """
    B, d = x.shape
    h = L.rms_norm(x, lp["ln1"]["scale"], cfg.rms_eps)
    q, k, v = L.qkv_project(h[:, None], lp["attn"], cfg.num_heads,
                            cfg.num_kv_heads, cfg.head_dim)
    q = L.apply_rope(q, positions[:, None], cfg.rope_theta)[:, 0]  # (B,H,D)
    k = L.apply_rope(k, positions[:, None], cfg.rope_theta)[:, 0]
    v = v[:, 0]
    q = lsc(q, "batch", "heads", None)
    kp = append_layer(kp, k, table, lengths)
    vp = append_layer(vp, v, table, lengths)
    new_len = lengths + 1
    kc = gather_layer(kp, table)                     # (B, M*bs, KH, D)
    vc = gather_layer(vp, table)

    ctx = None
    if shared is not None and cfg.moska.enabled:
        sk, sv, semb = _shared_layer(shared, x.dtype)
        routing = router_lib.route(q, semb, cfg.moska.top_k_chunks)
        ctx = MA.MoskaLayerContext(sk, sv, routing)
    o = MA.moska_decode_attention(q, kc, vc, new_len, ctx, cfg.moska,
                                  window=cfg.attn_window, kernel=kernel,
                                  layer_idx=layer_idx)
    x = x + _attn_out_proj(o, lp)
    h2 = L.rms_norm(x, lp["ln2"]["scale"], cfg.rms_eps)
    y, _ = _ffn(cfg, lp, h2[:, None])
    x = x + y[:, 0]
    return x, kp, vp


# ---------------------------------------------------------------------------
# full-model forwards (scan over layers)
# ---------------------------------------------------------------------------

def embed_inputs(cfg: ModelConfig, params: Params, tokens: jax.Array,
                 frontend_embeds: Optional[jax.Array] = None) -> jax.Array:
    x = params["embed"]["embed"][tokens]
    if frontend_embeds is not None:
        x = jnp.concatenate([frontend_embeds.astype(x.dtype), x], axis=1)
    return lsc(x, "batch", "seq", None)


def remat_policy(cfg: ModelConfig):
    return {
        "nothing": jax.checkpoint_policies.nothing_saveable,
        "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
    }[cfg.remat_policy]


def forward_hidden(cfg: ModelConfig, params: Params, x: jax.Array,
                   positions: jax.Array, *, remat: bool = True
                   ) -> Tuple[jax.Array, jax.Array]:
    """Run the layer stack (train path). Returns (hidden, moe_aux_sum)."""
    body_fn = partial(_layer_train, cfg)
    if remat and cfg.remat_policy != "none":
        body_fn = jax.checkpoint(body_fn, policy=remat_policy(cfg))

    def scan_body(carry, lp):
        x = carry
        x, aux = body_fn(x, lp, positions)
        return x, aux

    x, auxs = jax.lax.scan(scan_body, x, params["layers"])
    x = L.rms_norm(x, params["final_norm"]["scale"], cfg.rms_eps)
    return x, jnp.sum(auxs)


def lm_loss(cfg: ModelConfig, params: Params, hidden: jax.Array,
            targets: jax.Array, mask: jax.Array, *,
            seq_chunk: int = 512) -> jax.Array:
    """Chunked cross-entropy: never materializes (B, S, V) logits.

    hidden: (B, S, d); targets/mask: (B, S). Vocab stays sharded over the
    model axis inside each chunk.
    """
    B, S, d = hidden.shape
    W = unembed_matrix(cfg, params)                          # (V, d)
    seq_chunk = min(seq_chunk, S)
    nck = S // seq_chunk
    rem = S - nck * seq_chunk

    def chunk_loss(h, t, m):
        logits = jnp.einsum("bsd,vd->bsv", h, W,
                            preferred_element_type=jnp.float32)
        logits = lsc(logits, "batch", "seq", "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - ll) * m)

    def body(carry, xs):
        h, t, m = xs
        return carry + chunk_loss(h, t, m), None

    hs = hidden[:, : nck * seq_chunk].reshape(B, nck, seq_chunk, d)
    ts = targets[:, : nck * seq_chunk].reshape(B, nck, seq_chunk)
    ms = mask[:, : nck * seq_chunk].reshape(B, nck, seq_chunk)
    total, _ = jax.lax.scan(
        body, jnp.zeros((), jnp.float32),
        (hs.swapaxes(0, 1), ts.swapaxes(0, 1), ms.swapaxes(0, 1)))
    if rem:
        total = total + chunk_loss(hidden[:, nck * seq_chunk:],
                                   targets[:, nck * seq_chunk:],
                                   mask[:, nck * seq_chunk:])
    return total / jnp.maximum(jnp.sum(mask), 1.0)


def train_loss(cfg: ModelConfig, params: Params, batch: Dict[str, jax.Array],
               *, remat: bool = True) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    tokens = batch["tokens"]
    x = embed_inputs(cfg, params, tokens, batch.get("frontend_embeds"))
    S = x.shape[1]
    positions = jnp.arange(S)
    hidden, aux = forward_hidden(cfg, params, x, positions, remat=remat)
    P = S - tokens.shape[1]  # frontend positions carry no loss
    hidden_txt = hidden[:, P:]
    loss = lm_loss(cfg, params, hidden_txt, batch["targets"], batch["mask"])
    total = loss + aux
    return total, {"ce_loss": loss, "moe_aux": aux}


def _shared_xs(cfg: ModelConfig, store: Optional[SharedKVStore]):
    if store is None or not cfg.moska.enabled:
        return None
    d = {"k": store.k, "v": store.v, "emb": store.emb}
    if store.quantized:
        d["ks"] = store.k_scale
        d["vs"] = store.v_scale
    return d


def _shared_layer(sh, dtype):
    """Per-layer store slices; dequantizes int8 KV (the Pallas kernel does
    this in-register on TPU; the jnp path materializes the dequant)."""
    sk, sv, semb = sh["k"], sh["v"], sh["emb"]
    if "ks" in sh:
        sk = sk.astype(dtype) * sh["ks"][..., None].astype(dtype)
        sv = sv.astype(dtype) * sh["vs"][..., None].astype(dtype)
    return sk, sv, semb


def prefill(cfg: ModelConfig, params: Params, tokens: jax.Array,
            cache: KVCache, store: Optional[SharedKVStore] = None,
            frontend_embeds: Optional[jax.Array] = None,
            start_pos: int = 0,
            true_len: Optional[jax.Array] = None) -> Tuple[jax.Array, KVCache]:
    """Process the unique prefix; returns (last-token logits, filled cache).

    ``true_len`` (traced scalar ok): real prompt length when ``tokens`` is
    right-padded to a prefill bucket — logits are taken at position
    ``true_len - 1`` and the cache lengths record ``true_len``. Not
    supported together with ``frontend_embeds``.
    """
    if true_len is not None and frontend_embeds is not None:
        raise ValueError("true_len is not supported with frontend_embeds")
    x = embed_inputs(cfg, params, tokens, frontend_embeds)
    B, S, _ = x.shape
    positions = start_pos + jnp.arange(S)
    shared = _shared_xs(cfg, store)

    def scan_body(x, xs):
        if shared is not None:
            lp, kc, vc, li, sh = xs
        else:
            lp, kc, vc, li = xs
            sh = None
        x, kc, vc, _ = _layer_prefill(cfg, x, lp, positions, kc, vc, sh,
                                      jnp.asarray(start_pos),
                                      true_len=true_len, layer_idx=li)
        return x, (kc, vc)

    lidx = jnp.arange(cfg.num_layers)
    xs = ((params["layers"], cache.k, cache.v, lidx) if shared is None else
          (params["layers"], cache.k, cache.v, lidx, shared))
    x, (k_new, v_new) = jax.lax.scan(scan_body, x, xs)
    x = L.rms_norm(x, params["final_norm"]["scale"], cfg.rms_eps)
    if true_len is None:
        x_last = x[:, -1]
        n_valid = jnp.asarray(S, jnp.int32)
    else:
        n_valid = jnp.asarray(true_len, jnp.int32)
        x_last = jax.lax.dynamic_index_in_dim(x, n_valid - 1, axis=1,
                                              keepdims=False)
    logits = jnp.einsum("bd,vd->bv", x_last, unembed_matrix(cfg, params),
                        preferred_element_type=jnp.float32)
    lengths = jnp.full((B,), n_valid, jnp.int32)
    offsets = jnp.full((B,), start_pos, jnp.int32)
    return logits, KVCache(k_new, v_new, lengths, offsets)


def decode_step(cfg: ModelConfig, params: Params, tokens: jax.Array,
                cache: KVCache, store: Optional[SharedKVStore] = None,
                positions: Optional[jax.Array] = None,
                kernel: Optional[str] = None) -> Tuple[jax.Array, KVCache]:
    """One decode step. tokens: (B,). Returns (logits (B, V), new cache)."""
    x = params["embed"]["embed"][tokens]                     # (B, d)
    x = lsc(x, "batch", None)
    if positions is None:
        positions = cache.positions                          # absolute (RoPE)
    shared = _shared_xs(cfg, store)

    def scan_body(x, xs):
        if shared is not None:
            lp, kc, vc, li, sh = xs
        else:
            lp, kc, vc, li = xs
            sh = None
        x, kc, vc = _layer_decode(cfg, x, lp, positions, kc, vc,
                                  cache.length, sh, kernel=kernel,
                                  layer_idx=li)
        return x, (kc, vc)

    lidx = jnp.arange(cfg.num_layers)
    xs = ((params["layers"], cache.k, cache.v, lidx) if shared is None else
          (params["layers"], cache.k, cache.v, lidx, shared))
    x, (k_new, v_new) = jax.lax.scan(scan_body, x, xs)
    x = L.rms_norm(x, params["final_norm"]["scale"], cfg.rms_eps)
    logits = jnp.einsum("bd,vd->bv", x, unembed_matrix(cfg, params),
                        preferred_element_type=jnp.float32)
    return logits, KVCache(k_new, v_new, cache.length + 1, cache.offset)


def decode_step_paged(cfg: ModelConfig, params: Params, tokens: jax.Array,
                      pool: PagedKVCache, table: jax.Array,
                      lengths: jax.Array, offsets: jax.Array,
                      store: Optional[SharedKVStore] = None,
                      kernel: Optional[str] = None
                      ) -> Tuple[jax.Array, PagedKVCache]:
    """One decode step over the paged unique-KV pool.

    tokens: (B,); pool: physical pages (L, N, bs, KH, D); table: (B, M)
    int32 block tables; lengths/offsets: (B,) — the host-side mirror of the
    slotted cache's length/offset vectors (``SlotTables``). Returns
    (logits (B, V), new pool). The caller advances lengths (``tick``).
    """
    x = params["embed"]["embed"][tokens]                     # (B, d)
    x = lsc(x, "batch", None)
    positions = offsets + lengths                            # absolute (RoPE)
    shared = _shared_xs(cfg, store)

    def scan_body(x, xs):
        if shared is not None:
            lp, kp, vp, li, sh = xs
        else:
            lp, kp, vp, li = xs
            sh = None
        x, kp, vp = _layer_decode_paged(cfg, x, lp, positions, kp, vp,
                                        table, lengths, sh, kernel=kernel,
                                        layer_idx=li)
        return x, (kp, vp)

    lidx = jnp.arange(cfg.num_layers)
    xs = ((params["layers"], pool.k, pool.v, lidx) if shared is None else
          (params["layers"], pool.k, pool.v, lidx, shared))
    x, (k_new, v_new) = jax.lax.scan(scan_body, x, xs)
    x = L.rms_norm(x, params["final_norm"]["scale"], cfg.rms_eps)
    logits = jnp.einsum("bd,vd->bv", x, unembed_matrix(cfg, params),
                        preferred_element_type=jnp.float32)
    return logits, PagedKVCache(k_new, v_new)


# ---------------------------------------------------------------------------
# chunked prefill (long prompts, paged serving path)
# ---------------------------------------------------------------------------

def _layer_prefill_chunk(cfg: ModelConfig, x: jax.Array, lp: Params,
                         positions: jax.Array,
                         kc: jax.Array, vc: jax.Array,
                         base: jax.Array, chunk_len: jax.Array,
                         shared, start_pos: jax.Array,
                         layer_idx: Optional[jax.Array] = None
                         ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One chunk of a long prompt against the growing context view.

    x: (B, C, d) chunk activations (right-padded; ``chunk_len`` real);
    kc/vc: (B, V, KH, D) scratch context holding ``base`` earlier tokens;
    the chunk's fresh keys are written at ``base`` and causal attention
    runs over the whole view with ``kv_len = base + chunk_len`` masking.
    """
    h = L.rms_norm(x, lp["ln1"]["scale"], cfg.rms_eps)
    q, k, v = L.qkv_project(h, lp["attn"], cfg.num_heads, cfg.num_kv_heads,
                            cfg.head_dim)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    q = lsc(q, "batch", "seq", "heads", None)
    kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), base,
                                             axis=1)
    vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), base,
                                             axis=1)
    kv_valid = base + chunk_len

    if shared is not None and cfg.moska.enabled:
        sk, sv, semb = _shared_layer(shared, x.dtype)
        B, C, H, D = q.shape
        rb = min(128, C)
        nb = C // rb
        valid = (jnp.arange(C) < chunk_len).astype(q.dtype)        # (C,)
        qs = (q * valid[None, :, None, None]).reshape(B, nb, rb, H, D)
        cnt = jnp.maximum(valid.reshape(nb, rb).sum(axis=1), 1.0)
        pooled = (jnp.sum(qs, axis=2) /
                  cnt[None, :, None, None]).reshape(B * nb, H, D)
        routing = router_lib.route(pooled, semb, cfg.moska.top_k_chunks)
        o_u, lse_u = L.flash_attention(
            q, kc, vc, causal=True, q_offset=start_pos + base,
            kv_offset=start_pos, kv_len=kv_valid, window=cfg.attn_window,
            return_lse=True)
        part = sa.shared_attention_batched(
            q.reshape(B * nb, rb, H, D), sk, sv, routing,
            capacity_factor=cfg.moska.query_capacity_factor,
            layer_idx=layer_idx)
        o_s = part.out.reshape(B, C, H, D)
        lse_s = part.lse.reshape(B, C, H)
        o, _ = L.merge_partial_attention([o_u, o_s], [lse_u, lse_s])
    else:
        o = L.flash_attention(q, kc, vc, causal=True,
                              q_offset=start_pos + base,
                              kv_offset=start_pos, kv_len=kv_valid,
                              window=cfg.attn_window)
    x = x + lsc(_attn_out_proj(o, lp), "batch", "seq", None)
    h2 = L.rms_norm(x, lp["ln2"]["scale"], cfg.rms_eps)
    y, _ = _ffn(cfg, lp, h2)
    x = x + lsc(y, "batch", "seq", None)
    return x, kc, vc


def prefill_chunk(cfg: ModelConfig, params: Params, tokens: jax.Array,
                  cache: KVCache, store: Optional[SharedKVStore] = None,
                  start_pos=0,
                  chunk_len: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, KVCache]:
    """Process one chunk of a long prompt; call repeatedly to prefill
    prompts past the largest bucket with a bounded jit cache.

    tokens: (B, C) the chunk, right-padded; ``chunk_len`` (traced scalar)
    is the number of real tokens in it. ``cache`` is the scratch context
    (L, B, V, KH, D) already holding ``cache.length`` earlier tokens.
    Returns (logits at the chunk's last real token, cache extended by
    ``chunk_len``). One compiled program per (C, V) shape pair regardless
    of prompt length; numerically equivalent to the single-shot prefill
    (allclose), not bitwise (different contraction shapes).
    """
    x = embed_inputs(cfg, params, tokens)
    B, C, _ = x.shape
    base = cache.length[0]
    if chunk_len is None:
        chunk_len = jnp.asarray(C, jnp.int32)
    chunk_len = jnp.asarray(chunk_len, jnp.int32)
    start = jnp.asarray(start_pos, jnp.int32)
    positions = start + base + jnp.arange(C)
    shared = _shared_xs(cfg, store)

    def scan_body(x, xs):
        if shared is not None:
            lp, kc, vc, li, sh = xs
        else:
            lp, kc, vc, li = xs
            sh = None
        x, kc, vc = _layer_prefill_chunk(cfg, x, lp, positions, kc, vc,
                                         base, chunk_len, sh, start,
                                         layer_idx=li)
        return x, (kc, vc)

    lidx = jnp.arange(cfg.num_layers)
    xs = ((params["layers"], cache.k, cache.v, lidx) if shared is None else
          (params["layers"], cache.k, cache.v, lidx, shared))
    x, (k_new, v_new) = jax.lax.scan(scan_body, x, xs)
    x = L.rms_norm(x, params["final_norm"]["scale"], cfg.rms_eps)
    x_last = jax.lax.dynamic_index_in_dim(x, chunk_len - 1, axis=1,
                                          keepdims=False)
    logits = jnp.einsum("bd,vd->bv", x_last, unembed_matrix(cfg, params),
                        preferred_element_type=jnp.float32)
    lengths = (cache.length + chunk_len).astype(jnp.int32)
    offsets = jnp.full_like(cache.offset, start)
    return logits, KVCache(k_new, v_new, lengths, offsets)
