"""Mamba-2 (SSD — state-space duality) blocks [arXiv:2405.21060].

Attention-free: MoSKA's shared-KV mechanism is inapplicable (DESIGN.md
§Arch-applicability); the analogue provided is ``shared_state`` warm-start —
a precomputed SSM state summarizing a shared prefix, installed as the decode
initial state (the SSM rendering of prefix reuse; it summarizes rather than
indexes the corpus, so there is no routed sparse analogue).

Implements the chunked SSD algorithm (block decomposition of the
semiseparable matrix): intra-chunk quadratic part + inter-chunk state
recurrence via ``lax.scan``; single-step recurrence for decode.

Cache pytree: {"conv": (L, B, W-1, conv_dim), "state": (L, B, NH, P, N),
"length": (B,)}.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.sharding import lsc

Params = Dict[str, Any]


def _dims(cfg: ModelConfig) -> Tuple[int, int, int, int, int]:
    d_inner = cfg.d_model * cfg.ssm.expand
    P = cfg.ssm.head_dim
    NH = d_inner // P
    N = cfg.ssm.state_dim
    conv_dim = d_inner + 2 * N          # conv over [x, B, C]
    return d_inner, P, NH, N, conv_dim


def _layer_init(cfg: ModelConfig, key) -> Params:
    d = cfg.d_model
    di, P, NH, N, conv_dim = _dims(cfg)
    dtype = jnp.dtype(cfg.dtype)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    in_dim = 2 * di + 2 * N + NH        # z, x, B, C, dt
    s = 1.0 / math.sqrt(d)
    return {
        "ln1": {"scale": jnp.zeros((d,), dtype)},
        "in_proj": jax.random.normal(k1, (d, in_dim), dtype) * s,
        "conv_w": jax.random.normal(k2, (cfg.ssm.conv_width, conv_dim),
                                    dtype) * 0.1,
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, NH).astype(jnp.float32)),
        "d_skip": jnp.ones((NH,), jnp.float32),
        "dt_bias": jnp.zeros((NH,), jnp.float32) + math.log(math.e - 1),
        "gate_norm": {"scale": jnp.zeros((di,), dtype)},
        "out_proj": jax.random.normal(k4, (di, d), dtype) / math.sqrt(di),
    }


def init_params(cfg: ModelConfig, key) -> Params:
    ke, kl = jax.random.split(key)
    dtype = jnp.dtype(cfg.dtype)
    layer_keys = jax.random.split(kl, cfg.num_layers)
    return {
        "embed": {"embed": jax.random.normal(
            ke, (cfg.vocab_size, cfg.d_model), dtype) / math.sqrt(cfg.d_model)},
        "layers": jax.vmap(partial(_layer_init, cfg))(layer_keys),
        "final_norm": {"scale": jnp.zeros((cfg.d_model,), dtype)},
    }


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------

def _ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                 Cm: jax.Array, h0: jax.Array, chunk: int
                 ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    x: (B, S, NH, P); dt: (B, S, NH) (post-softplus); A: (NH,) negative;
    Bm/Cm: (B, S, N); h0: (B, NH, P, N). Returns (y: (B,S,NH,P), h_final).
    """
    Bsz, S, NH, P = x.shape
    N = Bm.shape[-1]
    S_orig = S
    rem = S % chunk
    if rem:
        # pad with dt=0 steps: a=exp(0)=1 (state unchanged), contribution 0
        pad = chunk - rem
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nck = S // chunk

    xc = x.reshape(Bsz, nck, chunk, NH, P).swapaxes(0, 1)
    dtc = dt.reshape(Bsz, nck, chunk, NH).swapaxes(0, 1)
    Bc = Bm.reshape(Bsz, nck, chunk, N).swapaxes(0, 1)
    Cc = Cm.reshape(Bsz, nck, chunk, N).swapaxes(0, 1)

    def body(h, xs):
        xq, dtq, Bq, Cq = xs                       # (B, Q, NH, P) etc.
        la = dtq * A[None, None, :]                # (B, Q, NH) log a_t <= 0
        s_cum = jnp.cumsum(la, axis=1)             # (B, Q, NH) = s_t
        # inter: y_t += C_t . exp(s_t) h_prev
        decay_t = jnp.exp(s_cum)                   # (B, Q, NH)
        y_inter = jnp.einsum("bqn,bhpn->bqhp", Cq, h) * decay_t[..., None]
        # intra: y_t += sum_{s<=t} exp(s_t - s_s) dt_s (C_t.B_s) x_s
        # L[t,s] per head; mask BEFORE exp (future entries have diff>0 and
        # would overflow — and exp-then-mask leaks inf into gradients)
        diff = s_cum[:, :, None, :] - s_cum[:, None, :, :]  # (B, Q, Q, NH)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        diff = jnp.where(tri[None, :, :, None], diff, -1e30)
        Lmat = jnp.exp(diff)
        cb = jnp.einsum("bqn,bsn->bqs", Cq, Bq)             # (B, Q, Q)
        att = cb[..., None] * Lmat * dtq[:, None, :, :]     # (B,Q,Q,NH)
        y_intra = jnp.einsum("bqsh,bshp->bqhp", att, xq)
        # state update: h = exp(s_Q) h + sum_s exp(s_Q - s_s) dt_s B_s x_s
        decay_rest = jnp.exp(s_cum[:, -1:, :] - s_cum)      # (B, Q, NH)
        w = dtq * decay_rest                                # (B, Q, NH)
        dh = jnp.einsum("bqh,bqn,bqhp->bhpn", w, Bq, xq)
        h_new = h * jnp.exp(s_cum[:, -1])[..., None, None] + dh
        return h_new, y_inter + y_intra

    h, yc = jax.lax.scan(body, h0, (xc, dtc, Bc, Cc))
    y = yc.swapaxes(0, 1).reshape(Bsz, S, NH, P)[:, :S_orig]
    return y, h


def _ssd_step(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
              Cm: jax.Array, h: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Single-token recurrence. x: (B, NH, P); dt: (B, NH); Bm/Cm: (B, N)."""
    a = jnp.exp(dt * A[None, :])                             # (B, NH)
    dh = jnp.einsum("bh,bn,bhp->bhpn", dt, Bm, x)
    h_new = h * a[..., None, None] + dh
    y = jnp.einsum("bn,bhpn->bhp", Cm, h_new)
    return y, h_new


# ---------------------------------------------------------------------------
# block forward
# ---------------------------------------------------------------------------

def _split_proj(cfg: ModelConfig, proj: jax.Array):
    di, P, NH, N, _ = _dims(cfg)
    z, xbc_dt = jnp.split(proj, [di], axis=-1)
    xbc, dt = jnp.split(xbc_dt, [di + 2 * N], axis=-1)
    return z, xbc, dt


def _conv_full(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Causal depthwise conv over (B, S, C) with kernel (W, C)."""
    W = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1]] * w[i][None, None] for i in range(W))
    return jax.nn.silu(out + b[None, None])


def _conv_step(x_t: jax.Array, conv_state: jax.Array, w: jax.Array,
               b: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x_t: (B, C); conv_state: (B, W-1, C) past inputs."""
    full = jnp.concatenate([conv_state, x_t[:, None]], axis=1)  # (B, W, C)
    out = jnp.einsum("bwc,wc->bc", full, w) + b[None]
    return jax.nn.silu(out), full[:, 1:]


def _block_full(cfg: ModelConfig, lp: Params, x: jax.Array,
                h0: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d). Returns (out, h_final)."""
    di, P, NH, N, _ = _dims(cfg)
    h = L.rms_norm(x, lp["ln1"]["scale"], cfg.rms_eps)
    proj = jnp.einsum("bsd,de->bse", h, lp["in_proj"])
    z, xbc, dt = _split_proj(cfg, proj)
    xbc = _conv_full(xbc, lp["conv_w"], lp["conv_b"])
    xs, Bm, Cm = jnp.split(xbc, [di, di + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"])
    A = -jnp.exp(lp["a_log"])
    xh = xs.reshape(*xs.shape[:2], NH, P).astype(jnp.float32)
    y, h_fin = _ssd_chunked(xh, dt, A, Bm.astype(jnp.float32),
                            Cm.astype(jnp.float32), h0, cfg.ssm.chunk_size)
    y = y + xh * lp["d_skip"][None, None, :, None]
    y = y.reshape(*xs.shape[:2], di).astype(x.dtype)
    y = L.rms_norm(y * jax.nn.silu(z), lp["gate_norm"]["scale"], cfg.rms_eps)
    return jnp.einsum("bsi,id->bsd", y, lp["out_proj"]), h_fin


def _block_step(cfg: ModelConfig, lp: Params, x: jax.Array, conv_state,
                h) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: (B, d) one token. Returns (out, new_conv_state, new_h)."""
    di, P, NH, N, _ = _dims(cfg)
    hn = L.rms_norm(x, lp["ln1"]["scale"], cfg.rms_eps)
    proj = jnp.einsum("bd,de->be", hn, lp["in_proj"])
    z, xbc, dt = _split_proj(cfg, proj)
    xbc, conv_state = _conv_step(xbc, conv_state, lp["conv_w"], lp["conv_b"])
    xs, Bm, Cm = jnp.split(xbc, [di, di + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"])
    A = -jnp.exp(lp["a_log"])
    xh = xs.reshape(-1, NH, P).astype(jnp.float32)
    y, h = _ssd_step(xh, dt, A, Bm.astype(jnp.float32),
                     Cm.astype(jnp.float32), h)
    y = y + xh * lp["d_skip"][None, :, None]
    y = y.reshape(-1, di).astype(x.dtype)
    y = L.rms_norm(y * jax.nn.silu(z), lp["gate_norm"]["scale"], cfg.rms_eps)
    return jnp.einsum("bi,id->bd", y, lp["out_proj"]), conv_state, h


# ---------------------------------------------------------------------------
# model-level API
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16, abstract: bool = False) -> Dict[str, Any]:
    di, P, NH, N, conv_dim = _dims(cfg)
    Lr = cfg.num_layers
    W = cfg.ssm.conv_width
    mk = (jax.ShapeDtypeStruct if abstract else
          lambda s, d: jnp.zeros(s, d))
    return {
        "conv": mk((Lr, batch, W - 1, conv_dim), dtype),
        "state": mk((Lr, batch, NH, P, N), jnp.float32),
        "length": mk((batch,), jnp.int32),
    }


def forward_hidden(cfg: ModelConfig, params: Params, x: jax.Array,
                   *, remat: bool = True) -> Tuple[jax.Array, jax.Array]:
    di, P, NH, N, _ = _dims(cfg)
    B = x.shape[0]
    h0 = jnp.zeros((B, NH, P, N), jnp.float32)

    body = partial(_block_full, cfg)
    if remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)

    def scan_body(x, lp):
        y, _ = body(lp, x, h0)
        return x + y, None

    x, _ = jax.lax.scan(scan_body, x, params["layers"])
    x = L.rms_norm(x, params["final_norm"]["scale"], cfg.rms_eps)
    return x, jnp.zeros((), jnp.float32)


def train_loss(cfg: ModelConfig, params: Params, batch: Dict[str, jax.Array],
               *, remat: bool = True):
    from repro.models.dense import lm_loss
    x = params["embed"]["embed"][batch["tokens"]]
    hidden, _ = forward_hidden(cfg, params, x, remat=remat)
    loss = lm_loss(cfg, params, hidden, batch["targets"], batch["mask"])
    return loss, {"ce_loss": loss, "moe_aux": jnp.zeros((), jnp.float32)}


def prefill(cfg: ModelConfig, params: Params, tokens: jax.Array, cache,
             store=None, frontend_embeds=None, start_pos: int = 0):
    """Prefill: run full sequence, producing final states for decode.

    ``store`` may be a shared warm-start state pytree {"state": (L,B,NH,P,N)}
    (the SSM analogue of the shared corpus: cache['state'] initialised from a
    precomputed shared-prefix state).
    """
    x = params["embed"]["embed"][tokens]
    B, S, _ = x.shape
    di, P, NH, N, conv_dim = _dims(cfg)
    W = cfg.ssm.conv_width
    h0_all = (store["state"] if store is not None else
              jnp.zeros((cfg.num_layers, B, NH, P, N), jnp.float32))

    def scan_body(x, xs):
        lp, h0 = xs
        y, h_fin = _block_full(cfg, lp, x, h0)
        # conv tail: last W-1 post-projection inputs for decode continuity
        hn = L.rms_norm(x, lp["ln1"]["scale"], cfg.rms_eps)
        proj = jnp.einsum("bsd,de->bse", hn, lp["in_proj"])
        _, xbc, _ = _split_proj(cfg, proj)
        conv_tail = xbc[:, -(W - 1):, :]
        return x + y, (conv_tail, h_fin)

    x, (conv_new, state_new) = jax.lax.scan(
        scan_body, x, (params["layers"], h0_all))
    x = L.rms_norm(x, params["final_norm"]["scale"], cfg.rms_eps)
    logits = jnp.einsum("bd,vd->bv", x[:, -1], params["embed"]["embed"],
                        preferred_element_type=jnp.float32)
    new_cache = {"conv": conv_new.astype(cache["conv"].dtype),
                 "state": state_new,
                 "length": jnp.full((B,), start_pos + S, jnp.int32)}
    return logits, new_cache


def decode_step(cfg: ModelConfig, params: Params, tokens: jax.Array, cache,
                store=None, positions=None, kernel=None):
    x = params["embed"]["embed"][tokens]

    def scan_body(x, xs):
        lp, conv_s, h = xs
        y, conv_s, h = _block_step(cfg, lp, x, conv_s, h)
        return x + y, (conv_s, h)

    x, (conv_new, state_new) = jax.lax.scan(
        scan_body, x, (params["layers"], cache["conv"], cache["state"]))
    x = L.rms_norm(x, params["final_norm"]["scale"], cfg.rms_eps)
    logits = jnp.einsum("bd,vd->bv", x, params["embed"]["embed"],
                        preferred_element_type=jnp.float32)
    new_cache = {"conv": conv_new, "state": state_new,
                 "length": cache["length"] + 1}
    return logits, new_cache


def shared_state(cfg: ModelConfig, params: Params,
                 corpus_tokens: jax.Array) -> Dict[str, jax.Array]:
    """Precompute the shared-prefix warm-start state (MoSKA analogue)."""
    B = corpus_tokens.shape[0]
    di, P, NH, N, conv_dim = _dims(cfg)
    cache = init_cache(cfg, B, corpus_tokens.shape[1])
    _, cache = prefill(cfg, params, corpus_tokens, cache)
    return {"state": cache["state"]}
