# model registry is imported lazily to avoid import cycles during bring-up
