"""Capacity-based (dropping) Mixture-of-Experts FFN.

Token dispatch uses the one-hot cumsum position trick (GShard/Switch) with
*scatter* data movement rather than the O(T·E·C·d) dispatch einsum, so HLO
FLOPs reflect real MoE compute (active-expert GEMMs only) — important for
honest roofline numbers. Experts are sharded over the ``model`` axis
(expert parallelism); the scatter/gather lower to all-to-all under pjit.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.sharding import lsc


def moe_init(key, d_model: int, d_ff: int, cfg: MoEConfig, dtype) -> dict:
    kr, k1, k2, k3 = jax.random.split(key, 4)
    E = cfg.num_experts
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(d_ff)
    return {
        "router": jax.random.normal(kr, (d_model, E), jnp.float32) * s_in,
        "e_gate": jax.random.normal(k1, (E, d_model, d_ff), dtype) * s_in,
        "e_up": jax.random.normal(k2, (E, d_model, d_ff), dtype) * s_in,
        "e_down": jax.random.normal(k3, (E, d_ff, d_model), dtype) * s_out,
    }


def moe_capacity(num_tokens: int, cfg: MoEConfig) -> int:
    cap = int(math.ceil(num_tokens * cfg.top_k * cfg.capacity_factor
                        / cfg.num_experts))
    return max(8, int(math.ceil(cap / 8) * 8))


def moe_ffn(x: jax.Array, p: dict, cfg: MoEConfig,
            capacity: int | None = None) -> Tuple[jax.Array, jax.Array]:
    """x: (T, d) flattened tokens -> (y: (T, d), aux_loss: scalar)."""
    T, d = x.shape
    E, K = cfg.num_experts, cfg.top_k
    if capacity is None:
        capacity = moe_capacity(T, cfg)
    capacity = min(capacity, T * K)

    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, K)                    # (T, K)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch): E * sum(frac_tokens * frac_probs)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(ids[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce) * cfg.aux_loss_weight

    flat = ids.reshape(-1)                                   # (T*K,)
    onehot = jax.nn.one_hot(flat, E, dtype=jnp.int32)
    pos = jnp.sum(jnp.cumsum(onehot, axis=0) * onehot, axis=1) - 1
    keep = pos < capacity
    drop_pos = jnp.where(keep, pos, capacity)

    x_slots = jnp.repeat(x, K, axis=0)                       # (T*K, d)
    xe = jnp.zeros((E, capacity, d), x.dtype)
    xe = xe.at[flat, drop_pos].set(x_slots, mode="drop")
    xe = lsc(xe, "experts", "expert_cap", "expert_dm")

    h = jnp.einsum("ecd,edf->ecf", xe, p["e_gate"])
    u = jnp.einsum("ecd,edf->ecf", xe, p["e_up"])
    h = jax.nn.silu(h) * u
    ye = jnp.einsum("ecf,efd->ecd", h, p["e_down"])
    ye = lsc(ye, "experts", "expert_cap", "expert_dm")

    y_slots = ye.at[flat, drop_pos].get(mode="fill", fill_value=0.0)
    y_slots = jnp.where(keep[:, None], y_slots, 0.0)
    y = jnp.sum(y_slots.reshape(T, K, d) * gates[..., None].astype(x.dtype),
                axis=1)
    return y.astype(x.dtype), aux
