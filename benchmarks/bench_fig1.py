"""Paper Fig. 1 — hardware requirement challenges.

(a) normalized KV cache size vs sequence length under common optimization
    stacks (GQA, quantization; sparsity does not shrink storage);
(b) memory capacity & bandwidth requirement scaling with batch size, with
    and without KV sharing — the motivation for Shared KV Attention.
Emits CSV rows: name,us_per_call,derived.
"""
from __future__ import annotations

from repro.core import analytical as A


def run(emit):
    seqs = [2**i for i in range(14, 25, 2)]
    fig1a = A.kv_cache_size_fig1a(seqs)
    base16m = fig1a["MHA fp16"][-1]
    for name, vals in fig1a.items():
        emit(f"fig1a/{name.replace(' ', '_')}@16M", 0.0,
             f"{vals[-1] / base16m:.4f}x_of_MHA_fp16")

    batches = [1, 4, 16, 64, 256]
    fig1b = A.bandwidth_scaling_fig1b(batches)
    for name in ("capacity_no_share", "capacity_shared",
                 "bandwidth_shared_gemv", "bandwidth_shared_gemm"):
        v = fig1b[name]
        emit(f"fig1b/{name}_scaling_b1_to_b256", 0.0,
             f"{v[-1] / max(v[0], 1e-9):.1f}x")
