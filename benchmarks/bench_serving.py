"""Measured serving throughput (reduced model, CPU): MoSKA engine vs the
same engine with the shared store disabled (per-request monolithic
context). The measured counterpart of Fig. 4's mechanism — KV reuse +
batched shared attention vs per-request recompute — at toy scale.

Numbers come from the engine's observability registry (``repro.obs``), not
ad-hoc timers, so this bench and the serving engine report the same
quantities: decode latency from ``engine/decode_step_latency_s``, token
counts from ``engine/tokens_generated``, corpus registration from the
``engine.register_corpus`` trace span. Each engine runs against its own
registry so the two configurations don't mix.
"""
from __future__ import annotations

import jax
import numpy as np

from repro import obs
from repro.configs import get_config
from repro.data.pipeline import CorpusSpec, synthesize_corpus
from repro.models.model import build_model
from repro.serving.engine import EngineConfig, ServingEngine


def _run_engine(cfg, params, ecfg, submits):
    """Run one engine on a fresh registry; returns the registry."""
    reg = obs.MetricsRegistry()
    prev = obs.set_registry(reg)
    try:
        eng = ServingEngine(cfg, params, ecfg)
        for corpus_id, corpus in submits.get("corpora", []):
            eng.register_corpus(corpus_id, corpus)
        for prompt, new, cid in submits["requests"]:
            eng.submit(prompt, max_new_tokens=new, corpus_id=cid)
        eng.run()
    finally:
        obs.set_registry(prev)
    return reg


def run(emit):
    cfg = get_config("tinyllama-1.1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    corpus = synthesize_corpus(CorpusSpec("d0", 256, cfg.vocab_size))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 8).tolist()
               for _ in range(6)]

    # MoSKA: corpus KV precomputed once, requests route into it
    reg = _run_engine(cfg, params, EngineConfig(max_slots=3, max_seq=64), {
        "corpora": [("d0", corpus)],
        "requests": [(p, 6, "d0") for p in prompts],
    })
    reg_spans = [s for s in reg.spans if s.name == "engine.register_corpus"]
    t_reg = sum(s.duration_s for s in reg_spans)
    toks = reg.counter("engine/tokens_generated").value
    t_moska = reg.gauge("engine/last_run_wall_s").value
    steps = int(reg.counter("engine/decode_steps").value)
    emit("serving/moska/register_corpus_us", t_reg * 1e6,
         f"{len(corpus)}tok_once")
    emit("serving/moska/decode_us_per_token",
         t_moska * 1e6 / max(toks, 1), f"steps={steps}")
    lat = reg.get("engine/decode_step_latency_s")
    if lat is not None and lat.count:
        emit("serving/moska/decode_step_mean_us", lat.mean * 1e6,
             f"p50<={lat.quantile(0.5) * 1e6:.0f}us n={lat.count}")
    util = reg.get("moska/dispatch_capacity_utilization")
    if util is not None and util.count:
        emit("serving/moska/dispatch_capacity_utilization", 0.0,
             f"{util.mean:.3f}")

    # baseline: no shared store; every request prefills corpus+prompt
    reg2 = _run_engine(cfg, params,
                       EngineConfig(max_slots=3, max_seq=320), {
                           "requests": [(corpus.tolist() + p, 6, None)
                                        for p in prompts],
                       })
    toks2 = reg2.counter("engine/tokens_generated").value
    t_base = reg2.gauge("engine/last_run_wall_s").value
    prefills = int(reg2.counter("engine/prefills").value)
    emit("serving/baseline_recompute/total_us_per_token",
         t_base * 1e6 / max(toks2, 1), f"prefills={prefills}")
    emit("serving/moska_speedup_incl_amortized_register", 0.0,
         f"{t_base / (t_moska + t_reg / len(prompts)):.2f}x")
