"""Measured serving throughput (reduced model, CPU): MoSKA engine vs the
same engine with the shared store disabled (per-request monolithic
context). The measured counterpart of Fig. 4's mechanism — KV reuse +
batched shared attention vs per-request recompute — at toy scale.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import CorpusSpec, synthesize_corpus
from repro.models.model import build_model
from repro.serving.engine import EngineConfig, ServingEngine


def run(emit):
    cfg = get_config("tinyllama-1.1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    corpus = synthesize_corpus(CorpusSpec("d0", 256, cfg.vocab_size))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 8).tolist()
               for _ in range(6)]

    # MoSKA: corpus KV precomputed once, requests route into it
    eng = ServingEngine(cfg, params, EngineConfig(max_slots=3, max_seq=64))
    t0 = time.perf_counter()
    eng.register_corpus("d0", corpus)
    t_reg = time.perf_counter() - t0
    for p in prompts:
        eng.submit(p, max_new_tokens=6, corpus_id="d0")
    t0 = time.perf_counter()
    eng.run()
    t_moska = time.perf_counter() - t0
    emit("serving/moska/register_corpus_us", t_reg * 1e6,
         f"{len(corpus)}tok_once")
    emit("serving/moska/decode_us_per_token",
         t_moska * 1e6 / max(eng.metrics["tokens_generated"], 1),
         f"steps={eng.metrics['decode_steps']}")

    # baseline: no shared store; every request prefills corpus+prompt
    eng2 = ServingEngine(cfg, params,
                         EngineConfig(max_slots=3, max_seq=320))
    t0 = time.perf_counter()
    for p in prompts:
        eng2.submit(corpus.tolist() + p, max_new_tokens=6)
    eng2.run()
    t_base = time.perf_counter() - t0
    emit("serving/baseline_recompute/total_us_per_token",
         t_base * 1e6 / max(eng2.metrics["tokens_generated"], 1),
         f"prefills={eng2.metrics['prefills']}")
    emit("serving/moska_speedup_incl_amortized_register", 0.0,
         f"{t_base / (t_moska + t_reg / len(prompts)):.2f}x")
