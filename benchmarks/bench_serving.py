"""Measured serving throughput (reduced model, CPU): MoSKA engine vs the
same engine with the shared store disabled (per-request monolithic
context). The measured counterpart of Fig. 4's mechanism — KV reuse +
batched shared attention vs per-request recompute — at toy scale.

Numbers come from the engine's observability registry (``repro.obs``), not
ad-hoc timers, so this bench and the serving engine report the same
quantities: decode latency from ``engine/decode_step_latency_s``, token
counts from ``engine/tokens_generated``, corpus registration from the
``engine.register_corpus`` trace span. Each engine runs against its own
registry so the two configurations don't mix.

Also benchmarks the zero-copy hot path (donated persistent cache vs
copying decode steps, ``engine/decode_cache_bytes_copied``), runs a
prompt-length sweep asserting the bucketed prefill jit cache stays bounded
(``engine/prefill_compile_count`` <= bucket count), and compares the paged
KV layout against the slotted one on a skewed prompt mix under an equal
memory budget (``record["paged_vs_slotted"]``: HBM high water, deferred
admissions, generation identity).

    PYTHONPATH=src python -m benchmarks.bench_serving --json-out BENCH_serving.json

writes the machine-readable result record (the perf-trajectory file
checked in as BENCH_serving.json; CI re-runs it as a smoke gate).
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro import obs
from repro.configs import get_config
from repro.data.pipeline import CorpusSpec, synthesize_corpus
from repro.models.model import build_model
from repro.serving.engine import (EngineConfig, ServingEngine,
                                  resolve_prefill_buckets)


def _run_engine(cfg, params, ecfg, submits):
    """Run one engine on a fresh registry; returns (registry, gens)."""
    reg = obs.MetricsRegistry()
    prev = obs.set_registry(reg)
    try:
        eng = ServingEngine(cfg, params, ecfg)
        for corpus_id, corpus in submits.get("corpora", []):
            eng.register_corpus(corpus_id, corpus)
        for prompt, new, cid in submits["requests"]:
            eng.submit(prompt, max_new_tokens=new, corpus_id=cid)
        done = eng.run()
    finally:
        obs.set_registry(prev)
    return reg, {r.uid: tuple(r.generated) for r in done}


def run(emit):
    cfg = get_config("tinyllama-1.1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    corpus = synthesize_corpus(CorpusSpec("d0", 256, cfg.vocab_size))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 8).tolist()
               for _ in range(6)]
    record = {"config": "tinyllama-1.1b/reduced", "metrics": {}}

    def rec(name, us, derived):
        record["metrics"][name] = {"us_per_call": round(us, 2),
                                   "derived": derived}
        emit(name, us, derived)

    # MoSKA: corpus KV precomputed once, requests route into it; decode
    # waves mutate the donated persistent cache (zero-copy hot path)
    reg, _ = _run_engine(cfg, params, EngineConfig(max_slots=3, max_seq=64), {
        "corpora": [("d0", corpus)],
        "requests": [(p, 6, "d0") for p in prompts],
    })
    reg_spans = [s for s in reg.spans if s.name == "engine.register_corpus"]
    t_reg = sum(s.duration_s for s in reg_spans)
    toks = reg.counter("engine/tokens_generated").value
    t_moska = reg.gauge("engine/last_run_wall_s").value
    steps = int(reg.counter("engine/decode_steps").value)
    rec("serving/moska/register_corpus_us", t_reg * 1e6,
        f"{len(corpus)}tok_once")
    rec("serving/moska/decode_us_per_token",
        t_moska * 1e6 / max(toks, 1), f"steps={steps}")
    lat = reg.get("engine/decode_step_latency_s")
    if lat is not None and lat.count:
        rec("serving/moska/decode_step_mean_us", lat.mean * 1e6,
            f"p50<={lat.quantile(0.5) * 1e6:.0f}us n={lat.count}")
        record["metrics"]["serving/moska/decode_step_p50_us"] = {
            "us_per_call": round(lat.quantile(0.5) * 1e6, 2),
            "derived": f"n={lat.count}"}
    rec("serving/moska/decode_cache_bytes_copied", 0.0,
        f"{int(reg.gauge('engine/decode_cache_bytes_copied').value)}B"
        f"_of_{int(reg.gauge('engine/decode_cache_bytes').value)}B")
    util = reg.get("moska/dispatch_capacity_utilization")
    if util is not None and util.count:
        rec("serving/moska/dispatch_capacity_utilization", 0.0,
            f"{util.mean:.3f}")

    # same workload with donation off: every decode step copies the cache
    reg_nd, _ = _run_engine(cfg, params,
                         EngineConfig(max_slots=3, max_seq=64,
                                      donate_cache=False), {
                             "corpora": [("d0", corpus)],
                             "requests": [(p, 6, "d0") for p in prompts],
                         })
    lat_nd = reg_nd.get("engine/decode_step_latency_s")
    if lat is not None and lat.count and lat_nd is not None and lat_nd.count:
        rec("serving/no_donation/decode_step_mean_us", lat_nd.mean * 1e6,
            f"donated_mean={lat.mean * 1e6:.0f}us")

    # baseline: no shared store; every request prefills corpus+prompt
    reg2, _ = _run_engine(cfg, params,
                       EngineConfig(max_slots=3, max_seq=320), {
                           "requests": [(corpus.tolist() + p, 6, None)
                                        for p in prompts],
                       })
    toks2 = reg2.counter("engine/tokens_generated").value
    t_base = reg2.gauge("engine/last_run_wall_s").value
    prefills = int(reg2.counter("engine/prefills").value)
    rec("serving/baseline_recompute/total_us_per_token",
        t_base * 1e6 / max(toks2, 1), f"prefills={prefills}")
    rec("serving/moska_speedup_incl_amortized_register", 0.0,
        f"{t_base / (t_moska + t_reg / len(prompts)):.2f}x")

    # prompt-length sweep: the bucketed prefill jit cache must stay bounded
    # (one program per bucket, not per distinct prompt length)
    sweep_lengths = [17, 18, 33, 34, 65, 66, 129, 130]
    reg3, _ = _run_engine(cfg, params,
                       EngineConfig(max_slots=2, max_seq=256), {
                           "corpora": [("d0", corpus)],
                           "requests": [([2] * n, 2, "d0")
                                        for n in sweep_lengths],
                       })
    buckets = resolve_prefill_buckets("auto", 256)
    compiles = int(reg3.gauge("engine/prefill_compile_count").value)
    rec("serving/prefill_sweep/compile_count", 0.0,
        f"{compiles}_programs_for_{len(sweep_lengths)}_lengths_"
        f"{len(buckets)}_buckets")
    record["prefill_sweep"] = {
        "prompt_lengths": sweep_lengths,
        "buckets": list(buckets),
        "bucket_count": len(buckets),
        "compile_count": compiles,
    }

    # paged vs slotted KV layout: same skewed prompt mix (one long prompt,
    # several short ones) under an equal unique-KV budget of 3 slots. The
    # slotted layout charges every request a full max_seq slab, so it runs
    # the queue 3 at a time; the paged pool charges only the blocks a
    # request can touch, fits the whole mix concurrently, and peaks lower.
    skew = [[2] * 40, [3] * 15] + [[4 + i] * 6 for i in range(4)]
    budget = 3 * 64 * cfg.kv_bytes_per_token
    pvs = {"prompt_lengths": [len(p) for p in skew],
           "mem_budget_bytes": budget}
    gens = {}
    for layout in ("slotted", "paged"):
        regp, gens[layout] = _run_engine(
            cfg, params,
            EngineConfig(max_slots=6, max_seq=64, kv_layout=layout,
                         mem_budget_bytes=budget), {
                "requests": [(p, 4, None) for p in skew],
            })
        pvs[layout] = {
            "hbm_high_water_bytes":
                int(regp.gauge("engine/hbm_high_water_bytes").value),
            "admissions_deferred":
                int(regp.counter("scheduler/admission_deferred_mem").value),
            "decode_waves": int(regp.counter("engine/decode_steps").value),
            "tokens": int(regp.counter("engine/tokens_generated").value),
        }
    pvs["identical_generations"] = gens["slotted"] == gens["paged"]
    record["paged_vs_slotted"] = pvs
    rec("serving/paged/hbm_high_water_bytes", 0.0,
        f"paged={pvs['paged']['hbm_high_water_bytes']}B_"
        f"slotted={pvs['slotted']['hbm_high_water_bytes']}B")
    rec("serving/paged/admissions_deferred", 0.0,
        f"paged={pvs['paged']['admissions_deferred']}_"
        f"slotted={pvs['slotted']['admissions_deferred']}")

    # host-tier offload vs rebuild-from-tokens: repeated cold prefix hits.
    # A tiny fixed pool (capacity 3 blocks) forces every parked prefix out
    # between waves; the same prompt stream then runs twice. With the host
    # tier, the second pass swaps pages back (no prefill); without it,
    # every cold hit re-prefills — same generations, strictly more
    # prefill tokens.
    cold_prompts = [[20 + i] * 8 for i in range(6)]
    ovr = {"prompt_tokens": sum(len(p) for p in cold_prompts),
           "passes": 2, "num_blocks": 4}
    gens_o = {}
    for name, host_blocks in (("swap_in", 16), ("rebuild", 0)):
        reg_o = obs.MetricsRegistry()
        prev = obs.set_registry(reg_o)
        try:
            eng = ServingEngine(cfg, params, EngineConfig(
                max_slots=2, max_seq=64, kv_layout="paged", block_size=16,
                num_blocks=4, host_pool_blocks=host_blocks))
            gen = {}
            for run_i in range(2):
                for p in cold_prompts:
                    eng.submit(p, max_new_tokens=4)
                for r in eng.run():
                    gen[(run_i, tuple(r.prompt))] = tuple(r.generated)
                eng.scheduler.finished.clear()
            gens_o[name] = gen
        finally:
            obs.set_registry(prev)
        ovr[name] = {
            "prefill_tokens":
                int(reg_o.counter("engine/prefill_tokens").value),
            "prefills": int(reg_o.counter("engine/prefills").value),
            "swap_in_hits":
                int(reg_o.counter("kvcache/swap_in_hits").value),
            "offload_bytes":
                int(reg_o.counter("kvcache/offload_bytes").value),
            "host_pool_evictions":
                int(reg_o.counter("kvcache/host_pool_evictions").value),
        }
    ovr["identical_generations"] = gens_o["swap_in"] == gens_o["rebuild"]
    record["offload_vs_rebuild"] = ovr
    rec("serving/offload/prefill_tokens", 0.0,
        f"swap_in={ovr['swap_in']['prefill_tokens']}_"
        f"rebuild={ovr['rebuild']['prefill_tokens']}")
    rec("serving/offload/swap_in_hits", 0.0,
        f"{ovr['swap_in']['swap_in_hits']}_of_{len(cold_prompts)}_cold_hits")

    # async pipeline vs fully synchronous serving: the same 2-pass
    # cold-prefix stream, host tier on in both configs. "overlap" runs
    # the engine defaults (prefetched swap-in + speculative boundary
    # pages + wave-overlap bookkeeping inside the dispatch window);
    # "sync" disables all three. Generations must be bit-identical —
    # the async layer moves work, never changes it — while the decode
    # stall (time blocked on the device after dispatch) must drop,
    # because the overlap window absorbs the host-side bookkeeping.
    avs = {"prompt_tokens": sum(len(p) for p in cold_prompts),
           "passes": 2, "num_blocks": 4, "host_pool_blocks": 16}
    gens_a = {}
    for name, async_on in (("overlap", True), ("sync", False)):
        reg_a = obs.MetricsRegistry()
        prev = obs.set_registry(reg_a)
        try:
            eng = ServingEngine(cfg, params, EngineConfig(
                max_slots=2, max_seq=64, kv_layout="paged", block_size=16,
                num_blocks=4, host_pool_blocks=16,
                prefetch_depth=2 if async_on else 0,
                spec_append=async_on, overlap_waves=async_on))
            gen = {}
            for run_i in range(2):
                for p in cold_prompts:
                    eng.submit(p, max_new_tokens=4)
                for r in eng.run():
                    gen[(run_i, tuple(r.prompt))] = tuple(r.generated)
                eng.scheduler.finished.clear()
            gens_a[name] = gen
        finally:
            obs.set_registry(prev)
        stall = reg_a.histogram("engine/decode_stall_s",
                                obs.LATENCY_EDGES_S)
        avs[name] = {
            "decode_stall_sum_s": round(stall.sum, 6),
            "decode_waves": stall.count,
            "prefetch_issued":
                int(reg_a.counter("kvcache/prefetch_issued").value),
            "prefetch_hits":
                int(reg_a.counter("kvcache/prefetch_hits").value),
            "prefetch_wasted":
                int(reg_a.counter("kvcache/prefetch_wasted").value),
            "spec_pages_alloc":
                int(reg_a.counter("kvcache/spec_pages_alloc").value),
            "swap_in_hits":
                int(reg_a.counter("kvcache/swap_in_hits").value),
        }
    avs["identical_generations"] = gens_a["overlap"] == gens_a["sync"]
    record["overlap_vs_sync"] = avs
    rec("serving/async/decode_stall_sum_s", 0.0,
        f"overlap={avs['overlap']['decode_stall_sum_s']}s_"
        f"sync={avs['sync']['decode_stall_sum_s']}s")
    rec("serving/async/prefetch_hits", 0.0,
        f"{avs['overlap']['prefetch_hits']}_hits_"
        f"{avs['overlap']['prefetch_issued']}_issued")
    return record


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json-out", default=None, metavar="PATH",
                    help="write the machine-readable result record "
                         "(BENCH_serving.json format)")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    record = run(lambda n, us, d: print(f"{n},{us:.2f},{d}", flush=True))
    record["backend"] = jax.default_backend()
    record["jax_version"] = jax.__version__
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(record, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"bench record -> {args.json_out}")
    return record


if __name__ == "__main__":
    main()
