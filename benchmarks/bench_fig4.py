"""Paper Fig. 4 — batch scaling capability and normalized throughput,
1M..16M shared context, all five methods; plus the headline max gain
(paper: up to 538.7x) under both decode-only and prefill-amortized
accounting, and the composable-corpus (prefix_fraction<1) variant that
quantifies §II.B's flexibility argument.
"""
from __future__ import annotations

import dataclasses

from repro.core import analytical as A


def run(emit):
    for pf, tag in ((1.0, "prefix"), (0.5, "composable")):
        w = dataclasses.replace(A.Workload(), prefix_fraction=pf)
        res = A.sweep_shared_context(w=w)
        for name, pts in res.items():
            for p in pts:
                mb = int(p.shared_tokens / 2**20)
                emit(f"fig4/{tag}/{name}/shared{mb}M/max_batch", 0.0,
                     p.max_batch)
                emit(f"fig4/{tag}/{name}/shared{mb}M/throughput_tok_s", 0.0,
                     f"{p.throughput:.1f}")
        moska = res["MoSKA"]
        fa = res["FlashAttention"]
        gains_dec = [m.throughput / max(f.throughput, 1e-9)
                     for m, f in zip(moska, fa)]
        gains_am = [m.throughput_amortized / max(f.throughput_amortized,
                                                 1e-9)
                    for m, f in zip(moska, fa)]
        emit(f"fig4/{tag}/max_gain_vs_FlashAttention_decode", 0.0,
             f"{max(gains_dec):.1f}x")
        emit(f"fig4/{tag}/max_gain_vs_FlashAttention_amortized", 0.0,
             f"{max(gains_am):.1f}x")
    # calibration: where the paper's 538.7x sits (see EXPERIMENTS.md)
    w = A.Workload()
    res = A.sweep_shared_context(w=w)
    for m, f in zip(res["MoSKA"], res["FlashAttention"]):
        g = m.throughput_amortized / max(f.throughput_amortized, 1e-9)
        if g >= 538.7:
            emit("fig4/amortized_gain_crosses_538.7x_at_shared_tokens",
                 0.0, int(m.shared_tokens))
            break
