"""Paper Fig. 5 — MFU and memory/bandwidth utilization of the Unique-KV
node vs the Shared-KV node as batch scales to 256 (16M shared context).
Validates the disaggregation claims: shared node goes compute-bound
(MFU > 80%), unique node stays memory-bound with linear capacity growth.
"""
from __future__ import annotations

from repro.core import analytical as A


def run(emit):
    batches = [1, 4, 16, 64, 256]
    pts = A.utilization_vs_batch(A.MOSKA, batches)
    for b, p in zip(batches, pts):
        emit(f"fig5/shared_node/b{b}/mfu", 0.0, f"{p.shared_node_mfu:.3f}")
        emit(f"fig5/shared_node/b{b}/mem_util", 0.0,
             f"{p.shared_node_mem:.3f}")
        emit(f"fig5/shared_node/b{b}/bw_util", 0.0,
             f"{p.shared_node_bw:.3f}")
        emit(f"fig5/unique_node/b{b}/mfu", 0.0, f"{p.unique_node_mfu:.4f}")
        emit(f"fig5/unique_node/b{b}/mem_util", 0.0,
             f"{p.unique_node_mem:.3f}")
        emit(f"fig5/unique_node/b{b}/bw_util", 0.0,
             f"{p.unique_node_bw:.3f}")
