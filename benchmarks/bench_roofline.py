"""§Roofline summary benchmark: reads results/dryrun/*.json (produced by
launch/dryrun.py) and emits the three roofline terms + dominant bottleneck
per (arch x shape x mesh). Run the dry-run first; rows appear only for
existing records.
"""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun_final")


def run(emit):
    files = sorted(glob.glob(os.path.join(RESULTS, "*.json")))
    if not files:
        emit("roofline/no_dryrun_records_found_run_launch.dryrun", 0.0, 0)
        return
    for f in files:
        with open(f) as fh:
            r = json.load(fh)
        tag = f"{r['arch']}/{r['shape']}/{r['mesh']}"
        if r["status"] == "skipped":
            emit(f"roofline/{tag}/skipped", 0.0, r.get("reason", "")[:60])
            continue
        if r["status"] != "ok":
            emit(f"roofline/{tag}/ERROR", 0.0, r.get("error", "")[:60])
            continue
        roof = r["roofline"]
        emit(f"roofline/{tag}/compute_s", 0.0, f"{roof['compute_s']:.3e}")
        emit(f"roofline/{tag}/memory_s", 0.0, f"{roof['memory_s']:.3e}")
        emit(f"roofline/{tag}/collective_s", 0.0,
             f"{roof['collective_s']:.3e}")
        emit(f"roofline/{tag}/dominant", 0.0, roof["dominant"])
        emit(f"roofline/{tag}/useful_flops", 0.0,
             f"{100 * roof['useful_flops_ratio']:.1f}%")
