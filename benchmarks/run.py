"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  bench_fig1      Fig. 1  KV size + capacity/bandwidth scaling
  bench_fig4      Fig. 4  batch capability + throughput, 5 methods
  bench_fig5      Fig. 5  disaggregated node MFU/memory utilization
  bench_kernels   Fig. 2a GEMV->GEMM intensity + kernel timings
  bench_serving   measured engine throughput vs recompute baseline
  bench_roofline  §Roofline terms from dry-run records
"""
import sys


def main() -> None:
    mods = ["bench_fig1", "bench_fig4", "bench_fig5", "bench_kernels",
            "bench_router", "bench_serving", "bench_roofline"]
    if len(sys.argv) > 1:
        mods = [m for m in mods if any(a in m for a in sys.argv[1:])]
    print("name,us_per_call,derived")
    failures = 0
    for name in mods:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        try:
            mod.run(lambda n, us, d: print(f"{n},{us:.2f},{d}", flush=True))
        except Exception as e:  # keep the harness going
            failures += 1
            print(f"{name}/ERROR,0.00,{type(e).__name__}:{e}", flush=True)
    if failures:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
