"""Router-quality ablation (supports the paper's 75%-sparsity assumption):
what fraction of true attention mass does the training-free mean-key
router's top-k capture, vs (a) oracle chunk ranking by actual attention
mass, (b) random chunk selection? Swept over k on a real (reduced) model's
corpus KV. The paper cites LongHeads/MoBA for ">=75% sparsity preserves
task performance"; this measures the mechanism on our stack.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs import get_config
from repro.core import build_store, route
from repro.kvcache import init_kv_cache
from repro.models import dense


def run(emit):
    cfg = dataclasses.replace(get_config("tinyllama-1.1b").reduced(),
                              dtype="float32")
    key = jax.random.PRNGKey(7)
    params = dense.init_params(cfg, key)
    E, C = 16, cfg.moska.chunk_size
    corpus = jax.random.randint(jax.random.fold_in(key, 1), (1, E * C), 0,
                                cfg.vocab_size)
    ccache = init_kv_cache(cfg.num_layers, 1, E * C, cfg.num_kv_heads,
                           cfg.head_dim, jnp.float32)
    _, ccache = dense.prefill(cfg, params, corpus, ccache)
    store = build_store(ccache.k[:, 0], ccache.v[:, 0], C)

    # queries from a forward pass over fresh prompts (layer-0 q)
    B = 16
    toks = jax.random.randint(jax.random.fold_in(key, 2), (B, 8), 0,
                              cfg.vocab_size)
    x = params["embed"]["embed"][toks]
    from repro.models import layers as L
    lp = jax.tree.map(lambda a: a[0], params["layers"])
    h = L.rms_norm(x, lp["ln1"]["scale"], cfg.rms_eps)
    q, _, _ = L.qkv_project(h, lp["attn"], cfg.num_heads, cfg.num_kv_heads,
                            cfg.head_dim)
    q = L.apply_rope(q, E * C + jnp.arange(8), cfg.rope_theta)[:, -1]

    # true attention mass per chunk (layer 0)
    KH, D = cfg.num_kv_heads, cfg.head_dim
    H = cfg.num_heads
    kf = store.k[0].reshape(E * C, KH, D)
    qg = q.reshape(B, KH, H // KH, D)
    s = jnp.einsum("bkgd,skd->bkgs", qg, kf) / math.sqrt(D)
    p = jax.nn.softmax(s, axis=-1)
    mass = p.reshape(B, KH, H // KH, E, C).sum(-1).mean((1, 2))  # (B, E)

    # record per-k quality into the observability registry, then report
    # from its snapshot — same metric names a serving deployment would see
    reg = obs.get_registry()
    rng = np.random.default_rng(0)
    for k in (1, 2, 4, 8):
        with obs.span("bench.route", registry=reg, top_k=k):
            r = route(q, store.emb[0], k)
            jax.block_until_ready(r.chunk_ids)
        routed = np.asarray(jax.vmap(
            lambda m, ids: m[ids].sum())(mass, r.chunk_ids))
        oracle = np.sort(np.asarray(mass), axis=1)[:, -k:].sum(1)
        rand_ids = rng.integers(0, E, (B, k))
        rand = np.take_along_axis(np.asarray(mass), rand_ids, 1).sum(1)
        base = f"router/top{k}_of_{E}"
        reg.set_gauge(f"{base}/mass_captured", float(routed.mean()))
        reg.set_gauge(f"{base}/oracle_mass", float(oracle.mean()))
        reg.set_gauge(f"{base}/random_mass", float(rand.mean()))
        reg.set_gauge(f"{base}/recall_vs_oracle",
                      float((routed / np.maximum(oracle, 1e-9)).mean()))
    snap = reg.snapshot()
    for name, m in snap.items():
        if name.startswith("router/"):
            emit(name, 0.0, f"{m['value']:.3f}")
    lat = reg.get("span/bench.route/duration_s")
    if lat is not None and lat.count:
        emit(f"router/route_call_mean_us_B{B}", lat.mean * 1e6,
             f"n={lat.count}")
