"""Kernel microbenchmarks (Fig. 2a in numbers): the GEMV->GEMM
transformation measured as arithmetic intensity + wall time of the jnp
reference paths on CPU, plus interpret-mode kernel parity timings.

The paper's claim in roofline terms: per-request GEMV over a shared chunk
has intensity ~O(G); batching N concurrent requests into one GEMM raises it
~O(N*G) — past the v5e ridge point (~240 flops/byte) at modest N.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import build_store, route, shared_attention_batched, \
    shared_attention_gather_ref
from repro.launch.mesh import HW


def _time(f, *args, n=5):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else None
    outs = f(*args)
    jax.tree.leaves(outs)[0].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(n):
        outs = f(*args)
    jax.tree.leaves(outs)[0].block_until_ready()
    return (time.perf_counter() - t0) / n * 1e6


def run(emit):
    key = jax.random.PRNGKey(0)
    E, C, KH, D, H = 8, 2048, 8, 128, 32
    G = H // KH
    kv = jax.random.normal(key, (1, E * C, KH, D), jnp.float32)
    store = build_store(kv, kv, C)
    kvb_per_chunk = 2 * C * KH * D * 4  # fp32 here

    for N in (1, 8, 64, 256):
        q = jax.random.normal(jax.random.fold_in(key, N), (N, 1, H, D),
                              jnp.float32)
        routing = route(q[:, 0], store.emb[0], 2)
        f_b = jax.jit(lambda q, r: shared_attention_batched(
            q, store.k[0], store.v[0], r))
        f_g = jax.jit(lambda q, r: shared_attention_gather_ref(
            q, store.k[0], store.v[0], r))
        t_b = _time(f_b, q, routing)
        t_g = _time(f_g, q, routing)
        # intensity: flops per byte of shared KV actually read
        flops = 4 * N * 2 * C * H * D       # 2 chunks/request
        bytes_gemv = N * 2 * kvb_per_chunk  # per-request re-read
        active = min(E, N * 2)
        bytes_gemm = active * kvb_per_chunk # read once per active chunk
        emit(f"kernels/shared_attn/N{N}/batched_us", t_b,
             f"intensity={flops/bytes_gemm:.1f}flops_per_byte")
        emit(f"kernels/shared_attn/N{N}/gather_gemv_us", t_g,
             f"intensity={flops/bytes_gemv:.1f}flops_per_byte")
    ridge = HW["peak_flops_bf16"] / HW["hbm_bw"]
    emit("kernels/v5e_ridge_point_flops_per_byte", 0.0, f"{ridge:.0f}")
