"""System behaviour tests: serving engine end-to-end, scheduler policy,
training loop convergence, checkpoint round-trip, data pipeline,
analytical-model fidelity (the paper's own claims), disaggregated
(shard_map) vs pjit-path equivalence."""
import dataclasses
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import analytical as A
from repro.core.scheduler import Scheduler, SchedulerConfig, wave_stats
from repro.data.pipeline import (CorpusSpec, SyntheticLMDataset,
                                 make_train_batches, synthesize_corpus)
from repro.models.model import build_model
from repro.serving.engine import EngineConfig, ServingEngine
from repro.training import checkpoint as ckpt
from repro.training.optimizer import adamw_init, adamw_update, cosine_schedule
from repro.training.train_loop import TrainLoopConfig, train

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# serving engine
# ---------------------------------------------------------------------------

def test_engine_end_to_end_with_shared_corpus():
    cfg = get_config("tinyllama-1.1b").reduced()
    model = build_model(cfg)
    params = model.init(KEY)
    eng = ServingEngine(cfg, params, EngineConfig(max_slots=3, max_seq=64))
    corpus = synthesize_corpus(CorpusSpec("laws", 256, cfg.vocab_size))
    n = eng.register_corpus("laws", corpus)
    assert n == 256 // cfg.moska.chunk_size
    for i in range(5):
        eng.submit([1 + i] * 8, max_new_tokens=4, corpus_id="laws")
    done = eng.run()
    assert len(done) == 5
    assert all(len(r.generated) == 4 for r in done)
    assert eng.metrics["tokens_generated"] == 20
    # continuous batching actually batched: fewer decode steps than
    # sequential (5 reqs x 4 tokens = 20 sequential; slots=3 => ~8)
    assert eng.metrics["decode_steps"] < 20


def test_engine_greedy_determinism():
    cfg = get_config("qwen1.5-0.5b").reduced()
    model = build_model(cfg)
    params = model.init(KEY)
    outs = []
    for _ in range(2):
        eng = ServingEngine(cfg, params,
                            EngineConfig(max_slots=2, max_seq=48))
        eng.submit([5, 6, 7, 8], max_new_tokens=6)
        outs.append(tuple(eng.run()[0].generated))
    assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------

def test_scheduler_slots_and_memory_budget():
    cfg = SchedulerConfig(max_slots=4, mem_budget_bytes=3 * 100 * 64,
                          unique_bytes_per_token=64, max_seq=100)
    s = Scheduler(cfg)
    for i in range(6):
        s.submit([1], 4, corpus_id="c0")
    admitted = s.schedule()
    # budget only fits 3 of 4 slots
    assert len(admitted) == 3
    for r in admitted:
        for _ in range(4):
            s.record_token(r, 0)
    assert all(r.done for r in admitted)
    nxt = s.schedule()
    assert len(nxt) == 3


def test_scheduler_corpus_affinity():
    s = Scheduler(SchedulerConfig(max_slots=2))
    s.submit([1], 1, corpus_id="a")
    s.submit([1], 1, corpus_id="b")
    s.submit([1], 1, corpus_id="a")
    admitted = s.schedule()
    # resident corpus 'a' preferred: both slots filled with 'a' requests
    assert [r.corpus_id for r in admitted] == ["a", "a"]
    stats = wave_stats(admitted)
    assert stats["max_corpus_batch"] == 2


# ---------------------------------------------------------------------------
# training substrate
# ---------------------------------------------------------------------------

def test_train_loss_decreases():
    cfg = dataclasses.replace(get_config("qwen1.5-0.5b").reduced(),
                              num_layers=2)
    loop = TrainLoopConfig(num_steps=30, batch_size=4, seq_len=64,
                           lr=1e-3, log_every=29)
    out = train(cfg, loop)
    hist = out["history"]
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.2, hist


def test_adamw_moves_params_and_clips():
    params = {"w": jnp.ones((4, 4))}
    state = adamw_init(params)
    grads = {"w": jnp.full((4, 4), 100.0)}  # should be clipped
    lr = cosine_schedule(1e-2, 1, 100)
    new, state2 = adamw_update(grads, state, params, lr=lr)
    assert not np.allclose(new["w"], params["w"])
    assert int(state2.step) == 1
    assert np.isfinite(np.asarray(new["w"])).all()


def test_checkpoint_roundtrip():
    cfg = get_config("tinyllama-1.1b").reduced()
    model = build_model(cfg)
    params = model.init(KEY)
    opt = adamw_init(params)
    with tempfile.TemporaryDirectory() as d:
        ckpt.save_checkpoint(d, 7, params, opt)
        path = ckpt.latest_checkpoint(d)
        step, p2, o2 = ckpt.restore_checkpoint(path, params, opt)
    assert step == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(opt), jax.tree.leaves(o2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_data_pipeline_deterministic_and_family_aware():
    cfg = get_config("internvl2-76b").reduced()
    b1 = next(make_train_batches(cfg, 2, 32, seed=3))
    b2 = next(make_train_batches(cfg, 2, 32, seed=3))
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert "frontend_embeds" in b1
    assert b1["tokens"].shape[1] + b1["frontend_embeds"].shape[1] == 32
    ds = SyntheticLMDataset(100, 16, seed=0)
    rows = next(ds.batches(4))
    assert rows["tokens"].max() < 100


# ---------------------------------------------------------------------------
# analytical model = the paper's §IV claims
# ---------------------------------------------------------------------------

def test_fig1b_bandwidth_scaling():
    """Sharing fixes capacity, not bandwidth (Fig. 1b)."""
    out = A.bandwidth_scaling_fig1b([1, 8, 64])
    cap_ns = out["capacity_no_share"]
    assert cap_ns[2] / cap_ns[0] == 64        # capacity scales w/o sharing
    assert out["capacity_shared"][0] == out["capacity_shared"][2]
    bw = out["bandwidth_shared_gemv"]
    assert bw[2] / bw[0] == 64                # GEMV bandwidth still scales
    gemm = out["bandwidth_shared_gemm"]
    assert gemm[0] == gemm[2]                 # MoSKA GEMM: flat


def test_fig4_method_ordering():
    """MoSKA >= ChunkAttention >> SGLang ~ FlashAttention at 16M."""
    res = A.sweep_shared_context()
    at16 = {k: v[-1] for k, v in res.items()}
    assert at16["MoSKA"].throughput > at16["ChunkAttention"].throughput
    assert at16["ChunkAttention"].throughput > 10 * at16["SGLang"].throughput
    assert at16["SGLang"].throughput == pytest.approx(
        at16["FlashAttention"].throughput, rel=0.3)
    # reuse methods hold far larger batches (Fig. 4 batch capability)
    assert at16["MoSKA"].max_batch > 50 * at16["FlashAttention"].max_batch


def test_fig5_node_utilization():
    """Shared node: MFU saturates >80% with batch; memory flat.
    Unique node: memory scales linearly; MFU stays tiny (Fig. 5)."""
    pts = A.utilization_vs_batch(A.MOSKA, [1, 16, 64, 256])
    assert pts[-1].shared_node_mfu >= 0.8
    assert pts[0].shared_node_mfu < 0.1
    assert pts[0].shared_node_mem == pts[-1].shared_node_mem  # loaded once
    assert pts[-1].unique_node_mem > 10 * pts[0].unique_node_mem
    assert pts[-1].unique_node_mfu < 0.1      # memory-bound GEMV pool


def test_headline_gain_exceeds_100x():
    gains = A.headline_gain()
    assert gains["FlashAttention"] > 100.0
    assert gains["LongHeads"] > 100.0


def test_size_host_pool_blocks():
    """Host-tier auto-sizing: cover the prefix working set minus what
    the device pool can keep resident (``--host-pool-blocks auto``)."""
    # elastic device pool: host tier sized to the full working set
    assert A.size_host_pool_blocks(128, 16) == 8
    assert A.size_host_pool_blocks(129, 16) == 9          # ceil
    # fixed pool: spare device blocks (capacity - null - active) offset
    # the host requirement
    assert A.size_host_pool_blocks(128, 16, device_pool_blocks=16,
                                   active_tokens=128) == 1
    assert A.size_host_pool_blocks(128, 16, device_pool_blocks=64,
                                   active_tokens=0) == 0  # all fits
    assert A.size_host_pool_blocks(0, 16) == 0
    with pytest.raises(ValueError):
        A.size_host_pool_blocks(128, 0)


# ---------------------------------------------------------------------------
# disaggregated shard_map path == pjit path (1-device degenerate mesh)
# ---------------------------------------------------------------------------

def test_disagg_shard_map_matches_batched():
    from repro.core import build_store, route, shared_attention_batched
    from repro.core.disagg import disaggregated_shared_attention
    from repro.configs.base import MoSKAConfig
    mesh = jax.make_mesh((1,), ("data",))
    E, C, KH, D, H, B = 4, 8, 2, 16, 4, 3
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, E * C, KH, D))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (1, E * C, KH, D))
    from repro.core import build_store as _bs
    store = _bs(k, v, C)
    q = jax.random.normal(jax.random.fold_in(KEY, 3), (B, H, D))
    cfg = MoSKAConfig(top_k_chunks=2)
    with mesh:
        o1, l1 = disaggregated_shared_attention(
            q, store.k[0], store.v[0], store.emb[0], cfg, mesh)
    r = route(q, store.emb[0], 2)
    part = shared_attention_batched(q[:, None], store.k[0], store.v[0], r,
                                    capacity_factor=cfg.query_capacity_factor)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(part.out[:, 0]),
                               rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(part.lse[:, 0]),
                               rtol=3e-5, atol=3e-5)
