import os

# smoke tests and benches must see the REAL device count (1 CPU device);
# only launch/dryrun.py forces 512 host devices. Keep determinism cheap.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_default_matmul_precision", "highest")
