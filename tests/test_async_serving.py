"""Async serving pipeline differentials: the overlap/prefetch/speculation
layer moves work, never changes it.

One cold-prefix workload (fixed 3-usable-block device pool, host tier on,
two passes over the same prompts — every pass-2 admission is a host-tier
swap-in) runs under the async engine defaults and under every disabled
combination; generations must be bit-identical across:

  * wave overlap on vs off (``overlap_waves``) — the same host-side
    bookkeeping inside vs after the device sync;
  * prefetched vs synchronous swap-in (``prefetch_depth``) — including
    the in-flight-wait path (on CPU every hit is taken at most one wave
    after issue, i.e. potentially mid-flight) and the stale-discard path
    (a one-block host tier churning under reversed arrival order);
  * speculative decode-boundary page allocation on vs off
    (``spec_append``) — including the wrong-speculation case where the
    request finishes on the boundary token and the page is reclaimed;
  * the slotted layout (the slab oracle, no paging at all).

The unit-level prefetch state properties (no pinning, no aliasing while
pending, transfer conservation) live in ``test_kvpool_stateful.py``;
this suite checks the engine wiring end to end.
"""
import os

import numpy as np
import pytest

import jax

from repro import obs
from repro.configs import get_config
from repro.models.model import build_model
from repro.serving.engine import EngineConfig, ServingEngine

_STATE = {}

COLD_PROMPTS = [[30 + i] * 8 for i in range(4)]

REF_LAYOUT = os.environ.get("HOST_OFFLOAD_REF_LAYOUT", "slotted")

#: all async features off — the PR 9 synchronous engine, exactly
SYNC = dict(prefetch_depth=0, spec_append=False, overlap_waves=False)


def _setup():
    if not _STATE:
        cfg = get_config("tinyllama-1.1b").reduced()
        model = build_model(cfg)
        _STATE["cfg"] = cfg
        _STATE["params"] = model.init(jax.random.PRNGKey(0))
    return _STATE["cfg"], _STATE["params"]


def _run(layout, prompts=COLD_PROMPTS, passes=2, reverse_odd=False,
         max_new=4, **kw):
    """Run ``passes`` waves of ``prompts`` on a fresh engine; returns
    ((pass, prompt)-keyed generations, metrics snapshot, engine)."""
    cfg, params = _setup()
    obs.reset_registry()
    eng = ServingEngine(cfg, params,
                        EngineConfig(max_slots=2, max_seq=64,
                                     kv_layout=layout, **kw))
    gens = {}
    for i in range(passes):
        wave = prompts[::-1] if (reverse_odd and i % 2) else prompts
        for p in wave:
            eng.submit(p, max_new_tokens=max_new)
        for r in eng.run():
            gens[(i, tuple(r.prompt))] = tuple(r.generated)
        eng.scheduler.finished.clear()
    return gens, obs.get_registry().snapshot(), eng


def _ref_run(**kw):
    if REF_LAYOUT == "paged":
        return _run("paged", block_size=16, num_blocks=64, **SYNC, **kw)
    return _run("slotted", **kw)


def _counter(snap, name):
    return int(snap.get(name, {}).get("value", 0))


def _hist(snap, name):
    return snap.get(name, {})


def test_async_differential_bit_identical():
    """The headline contract: async defaults vs each feature disabled vs
    fully-sync vs the reference layout — identical generations, and the
    async run actually exercised the prefetch path."""
    paged = dict(block_size=16, num_blocks=4, host_pool_blocks=16)
    ref, _, _ = _ref_run()
    full, fsnap, feng = _run("paged", **paged)                 # defaults on
    sync, ssnap, _ = _run("paged", **paged, **SYNC)
    noov, _, _ = _run("paged", **paged, overlap_waves=False)
    nopf, _, _ = _run("paged", **paged, prefetch_depth=0)
    nosp, _, _ = _run("paged", **paged, spec_append=False)

    assert full == ref
    assert sync == ref
    assert noov == ref
    assert nopf == ref
    assert nosp == ref

    # pass 2 swap-ins were served from prefetched transfers
    assert _counter(fsnap, "kvcache/prefetch_issued") >= 1
    assert _counter(fsnap, "kvcache/prefetch_hits") >= 1
    assert _counter(fsnap, "kvcache/prefetch_hits") <= \
        _counter(fsnap, "kvcache/swap_in_hits")
    # the sync config runs no async machinery at all
    for name in ("kvcache/prefetch_issued", "kvcache/prefetch_hits",
                 "kvcache/spec_pages_alloc", "engine/overlap_saved_s"):
        assert name not in ssnap
    # overlap bookkeeping was measured, and the engine drained clean:
    # no transfer left in flight, no speculative page left pending
    assert _hist(fsnap, "engine/overlap_saved_s").get("count", 0) >= 1
    assert _hist(fsnap, "engine/decode_stall_s").get("count", 0) >= 1
    assert feng._prefetch.in_flight == 0 or \
        feng._prefetch.in_flight <= feng._prefetch.depth
    assert not feng._spec_pending


def test_prefetch_stale_discard_under_host_churn():
    """One-block host tier + reversed second pass: entries are evicted
    between issue and admission, so transfers go stale — they must be
    discarded (counted wasted), with generations unaffected."""
    paged = dict(block_size=16, num_blocks=4, host_pool_blocks=1)
    ref, _, _ = _ref_run(reverse_odd=True)
    churn, csnap, ceng = _run("paged", reverse_odd=True, **paged)
    churn_sync, _, _ = _run("paged", reverse_odd=True, **paged, **SYNC)
    assert churn == ref
    assert churn_sync == ref
    # conservation across the whole run: everything issued was either
    # resolved into a hit or discarded as stale — nothing leaked
    pf = ceng._prefetch
    assert pf.resolved + pf.discarded + pf.in_flight == pf.issued
    assert _counter(csnap, "kvcache/prefetch_hits") + \
        _counter(csnap, "kvcache/prefetch_wasted") + pf.in_flight == \
        _counter(csnap, "kvcache/prefetch_issued")


def test_speculative_append_used_and_reclaimed():
    """Prompt of 8 + block size 16: the 8th generated token fills the
    first page, so the 9th opens a new one. ``max_new=9`` finishes ON
    the boundary — the speculated page is never written and must be
    reclaimed; ``max_new=12`` writes into it. Both bit-identical to the
    spec-off engine."""
    paged = dict(block_size=16, num_blocks=64, host_pool_blocks=0,
                 passes=1)
    prompts = [[40] * 8]

    used, usnap, ueng = _run("paged", prompts=prompts, max_new=12, **paged)
    used_off, osnap, _ = _run("paged", prompts=prompts, max_new=12,
                              spec_append=False, **paged)
    assert used == used_off
    assert _counter(usnap, "kvcache/spec_pages_alloc") == 1
    assert _counter(usnap, "kvcache/spec_pages_reclaimed") == 0
    assert not ueng._spec_pending     # consumed by the next wave
    # page accounting conservation: the speculated append replaces the
    # synchronous one, it doesn't add to it
    assert _counter(usnap, "kvcache/blocks_appended") == \
        _counter(osnap, "kvcache/blocks_appended")

    recl, rsnap, reng = _run("paged", prompts=prompts, max_new=9, **paged)
    recl_off, _, _ = _run("paged", prompts=prompts, max_new=9,
                          spec_append=False, **paged)
    assert recl == recl_off
    assert _counter(rsnap, "kvcache/spec_pages_alloc") == 1
    assert _counter(rsnap, "kvcache/spec_pages_reclaimed") == 1
    assert not reng._spec_pending
    # the reclaimed page went back to the free list with the slot
    assert reng._block_pool.in_use == \
        sum(len(e["blocks"]) for e in reng._prefix_cache.values())


def test_spec_append_defers_when_pool_full():
    """A full free list must defer speculation to the synchronous append
    path (which can evict parked prefixes), never evict or raise itself
    — and stay bit-identical. num_blocks=3 leaves 2 usable pages: a
    short request parks its page in the prefix cache, so when the long
    request hits its page boundary the free list is empty; only the
    synchronous append (one wave later) may evict the parked page."""
    cfg, params = _setup()

    def go(spec):
        obs.reset_registry()
        eng = ServingEngine(cfg, params, EngineConfig(
            max_slots=2, max_seq=64, kv_layout="paged", block_size=16,
            num_blocks=3, host_pool_blocks=0, spec_append=spec))
        eng.submit([50] * 8, max_new_tokens=4)    # parks 1 page early
        eng.submit([51] * 8, max_new_tokens=12)   # crosses the boundary
        gens = {tuple(r.prompt): tuple(r.generated) for r in eng.run()}
        return gens, obs.get_registry().snapshot()

    on, osnap = go(True)
    off, _ = go(False)
    assert on == off
    # the boundary wave found the pool full: speculation deferred, the
    # synchronous path evicted the parked prefix and appended
    assert _counter(osnap, "kvcache/spec_pages_alloc") == 0
    assert _counter(osnap, "kvcache/prefix_evictions") >= 1
    assert _counter(osnap, "kvcache/blocks_appended") >= 1


def test_prefetch_depth_bounds_inflight():
    """--prefetch-depth 1 on the cold stream: never more than one
    transfer in flight, still bit-identical, still hits."""
    paged = dict(block_size=16, num_blocks=4, host_pool_blocks=16)
    ref, _, _ = _ref_run()
    d1, dsnap, deng = _run("paged", prefetch_depth=1, **paged)
    assert d1 == ref
    assert deng._prefetch.depth == 1
    assert _counter(dsnap, "kvcache/prefetch_issued") >= 1
    assert _counter(dsnap, "kvcache/prefetch_hits") >= 1


def test_wave_hooks_fire_per_decode_wave():
    """wave_hooks run once per decode wave in both layouts (the
    streaming exporter's attachment point)."""
    cfg, params = _setup()
    for layout, kw in (("slotted", {}),
                       ("paged", dict(block_size=16, num_blocks=16))):
        obs.reset_registry()
        eng = ServingEngine(cfg, params, EngineConfig(
            max_slots=2, max_seq=64, kv_layout=layout, **kw))
        calls = []
        eng.wave_hooks.append(lambda: calls.append(1))
        eng.submit([60] * 8, max_new_tokens=4)
        eng.run()
        waves = int(obs.get_registry().counter(
            "engine/decode_steps").value)
        assert waves >= 1 and len(calls) == waves
