"""Stateful property suite for the two-tier KV block pool.

A single model (:class:`_TwoTierModel`) drives random interleavings of
alloc / incref (prefix share) / CoW / free / offload / swap-in / prefetch
issue / resolve / stale-sweep against a real ``BlockPool`` +
``HostBlockPool`` + ``PrefetchEngine`` triple, shadowing them with pure
Python bookkeeping, and checks after every step:

  * refcount conservation — the pool's refcounts equal the model's for
    every block, and free + live == capacity (free-list integrity);
  * no double-free / no incref-of-free — both raise, and a freed block
    only ever returns to the free list once;
  * no device/host page aliasing — a host entry is a verbatim *copy*:
    its payload still equals the offload-time snapshot after the source
    blocks were recycled and overwritten, and the generation tags prove
    it (a source block whose generation is unchanged since offload must
    still be free; any reuse bumped it);
  * host-tier integrity — block accounting matches the entries, capacity
    is never exceeded, eviction is LRU;
  * prefetch integrity — issuing a transfer pins nothing (no device
    refcount change, host LRU order untouched), a pending transfer never
    aliases its source (it holds the issue-time snapshot even after the
    host entry is evicted and the device blocks recycled), and the
    engine's transfer conservation (resolved + discarded + in-flight ==
    issued) holds at every step.

The hypothesis rule-based state machine explores random interleavings
when hypothesis is installed; the deterministic fallback walks (seeded
rng over the same model) always run.
"""
import numpy as np
import pytest

from repro.kvcache.paged import BlockPool, HostBlockPool, PoolExhausted
from repro.kvcache.transfer import PrefetchEngine

try:
    from hypothesis import settings
    from hypothesis.stateful import (RuleBasedStateMachine, initialize,
                                     invariant, rule)
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


class _TwoTierModel:
    """Shadow model + operation vocabulary shared by the hypothesis state
    machine and the deterministic fallback walks."""

    def __init__(self, num_blocks: int, host_blocks: int,
                 prefetch_depth: int = 2):
        self.pool = BlockPool(num_blocks)
        self.host = HostBlockPool(host_blocks)
        self.prefetch = PrefetchEngine(self.host, prefetch_depth)
        self.tables = []          # live mappings: lists of block ids
        self.refs = {}            # block -> model refcount
        self.content = {}         # block -> payload currently on "device"
        self.expected = {}        # host key -> (payloads, gens) snapshot
        self.inflight = {}        # issued key -> issue-time snapshot
        self._payload = 0.0
        self._key = 0

    # -- helpers ---------------------------------------------------------
    def _fresh_payload(self) -> float:
        self._payload += 1.0
        return self._payload

    def _pages(self, payloads):
        """Fake (L, nb, bs, KH, D) device pages holding one payload per
        block — enough to detect any bit of aliasing or reordering."""
        arr = np.asarray(payloads, np.float32).reshape(1, -1, 1, 1, 1)
        return arr, arr + 0.5

    # -- operations ------------------------------------------------------
    def op_alloc(self, n: int):
        gens_before = {b: self.pool.generation(b)
                       for b in range(self.pool.num_blocks)}
        try:
            ids = self.pool.alloc(n)
        except PoolExhausted:
            assert self.pool.available < n
            return
        assert len(set(ids)) == n
        for b in ids:
            # every hand-out bumps the block's generation exactly once
            assert self.pool.generation(b) == gens_before[b] + 1
            assert b not in self.refs, "allocated a live block"
            self.refs[b] = 1
            self.content[b] = self._fresh_payload()
        self.tables.append(list(ids))

    def op_share(self, i: int):
        if not self.tables:
            return
        t = self.tables[i % len(self.tables)]
        self.pool.incref(t)
        for b in t:
            self.refs[b] += 1
        self.tables.append(list(t))

    def op_cow(self, i: int, j: int):
        if not self.tables:
            return
        t = self.tables[i % len(self.tables)]
        b = t[j % len(t)]
        if not self.pool.needs_copy(b):
            return
        try:
            new = self.pool.alloc(1)[0]
        except PoolExhausted:
            return
        self.refs[new] = 1
        self.content[new] = self.content[b]
        self.pool.free([b])
        self.refs[b] -= 1
        t[t.index(b)] = new

    def op_release(self, i: int):
        if not self.tables:
            return
        t = self.tables.pop(i % len(self.tables))
        self.pool.free(t)
        for b in t:
            self.refs[b] -= 1
            if self.refs[b] == 0:
                del self.refs[b]

    def op_offload(self, i: int):
        """Evict a cold mapping (all blocks refcount 1, like a prefix
        entry owned only by the cache) through the host tier."""
        if not self.tables:
            return
        i %= len(self.tables)
        t = self.tables[i]
        if any(self.refs[b] != 1 for b in t):
            return
        payloads = tuple(self.content[b] for b in t)
        gens = tuple((b, self.pool.generation(b)) for b in t)
        k, v = self._pages(payloads)
        self._key += 1
        key = f"entry-{self._key}"
        stored_before = key in self.host
        assert not stored_before
        evicted = self.host.offload(key, k, v, first=7, gens=gens)
        for ek in evicted:
            del self.expected[ek]
        if key in self.host:
            self.expected[key] = (payloads, gens)
        else:                      # wider than the whole host pool
            assert len(t) > self.host.capacity_blocks or \
                self.host.capacity_blocks == 0
        self.tables.pop(i)
        self.pool.free(t)
        for b in t:
            del self.refs[b]

    def op_swap_in(self, i: int):
        if not self.expected:
            return
        key = sorted(self.expected)[i % len(self.expected)]
        payloads, gens = self.expected[key]
        if self.pool.available < len(payloads):
            return
        entry = self.host.fetch(key)
        assert entry is not None
        del self.expected[key]
        # no aliasing: the host copy still equals the offload-time
        # snapshot, regardless of what happened to the source blocks
        got = np.asarray(entry["k"]).reshape(-1)
        np.testing.assert_array_equal(got, np.asarray(payloads, np.float32))
        np.testing.assert_array_equal(np.asarray(entry["v"]).reshape(-1),
                                      got + 0.5)
        assert entry["gens"] == gens
        for b, g in gens:
            # an unchanged generation means the source block was never
            # reused since offload — it must still be on the free list
            if self.pool.generation(b) == g:
                assert self.pool.is_free(b), \
                    f"block {b} live with stale generation {g}"
            else:
                assert self.pool.generation(b) > g
        ids = self.pool.alloc(len(payloads))
        for b, p in zip(ids, payloads):
            self.refs[b] = 1
            self.content[b] = p
        self.tables.append(list(ids))

    def op_prefetch_issue(self, i: int):
        """Issue a host->device prefetch for a resident host entry: must
        pin nothing and leave the host tier's LRU order untouched."""
        if not self.expected:
            return
        key = sorted(self.expected)[i % len(self.expected)]
        lru_before = list(self.host.keys())
        ok = self.prefetch.issue(key)
        if ok:
            assert key not in self.inflight
            self.inflight[key] = self.expected[key]
        else:
            # the only legal refusals: already in flight, or at depth
            assert key in self.inflight or \
                self.prefetch.in_flight >= self.prefetch.depth
        assert list(self.host.keys()) == lru_before, \
            "prefetch issue perturbed the host LRU order"

    def op_prefetch_resolve(self, i: int):
        """Take a transfer whose host entry is still resident (the
        engine's hit path): the payload must equal the issue-time
        snapshot and the generation tags must still match the entry."""
        live = [k for k in sorted(self.inflight) if k in self.expected]
        if not live:
            return
        key = live[i % len(live)]
        tr = self.prefetch.take(key)
        assert tr is not None
        payloads, gens = self.inflight.pop(key)
        assert tr["gens"] == gens
        got = np.asarray(tr["k"]).reshape(-1)
        np.testing.assert_array_equal(got, np.asarray(payloads, np.float32))
        np.testing.assert_array_equal(np.asarray(tr["v"]).reshape(-1),
                                      got + 0.5)

    def op_prefetch_sweep(self):
        """Discard transfers whose host entry churned since issue. Before
        the sweep, every stale transfer must still hold its pristine
        issue-time snapshot (no aliasing while pending)."""
        stale = [k for k in self.inflight if k not in self.expected]
        for k in stale:
            payloads, _ = self.inflight[k]
            pend = self.prefetch._inflight[k]
            np.testing.assert_array_equal(
                np.asarray(pend["k"]).reshape(-1),
                np.asarray(payloads, np.float32))
        assert self.prefetch.sweep() == len(stale)
        for k in stale:
            del self.inflight[k]

    def op_bad_calls(self, b: int):
        """Double-free and incref-of-free must raise and mutate nothing."""
        b = 1 + (b % (self.pool.num_blocks - 1))
        if not self.pool.is_free(b):
            return
        before = self.pool.available
        with pytest.raises(ValueError):
            self.pool.free([b])
        with pytest.raises(ValueError):
            self.pool.incref([b])
        assert self.pool.available == before

    # -- invariants ------------------------------------------------------
    def check(self):
        self.pool.check_invariants()
        self.host.check_invariants()
        self.prefetch.check_invariants()
        for b in range(1, self.pool.num_blocks):
            assert self.pool.refcount(b) == self.refs.get(b, 0), \
                f"refcount drift on block {b}"
        assert set(self.host.keys()) == set(self.expected)
        assert self.host.used_blocks == \
            sum(len(p) for p, _ in self.expected.values())
        assert set(self.prefetch.keys()) == set(self.inflight)

    def drain(self):
        while self.tables:
            self.op_release(0)
        self.check()
        assert self.pool.in_use == 0
        assert self.pool.available == self.pool.capacity


_OPS = ("alloc", "share", "cow", "release", "offload", "swap_in", "bad",
        "pf_issue", "pf_resolve", "pf_sweep")


def _walk(model: _TwoTierModel, rng, steps: int):
    for _ in range(steps):
        op = _OPS[rng.integers(0, len(_OPS))]
        i = int(rng.integers(0, 1 << 16))
        if op == "alloc":
            model.op_alloc(int(rng.integers(1, 4)))
        elif op == "share":
            model.op_share(i)
        elif op == "cow":
            model.op_cow(i, int(rng.integers(0, 1 << 16)))
        elif op == "release":
            model.op_release(i)
        elif op == "offload":
            model.op_offload(i)
        elif op == "swap_in":
            model.op_swap_in(i)
        elif op == "pf_issue":
            model.op_prefetch_issue(i)
        elif op == "pf_resolve":
            model.op_prefetch_resolve(i)
        elif op == "pf_sweep":
            model.op_prefetch_sweep()
        else:
            model.op_bad_calls(i)
        model.check()
    model.drain()


# ---------------------------------------------------------------------------
# deterministic fallback walks (always run)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed,num_blocks,host_blocks,steps", [
    (0, 16, 4, 300),
    (1, 6, 2, 250),      # tight device pool: exhaustion paths
    (2, 12, 0, 200),     # host tier disabled: offload degrades to drop
    (3, 8, 1, 250),      # one-block host tier: constant LRU churn
])
def test_two_tier_deterministic_walk(seed, num_blocks, host_blocks, steps):
    _walk(_TwoTierModel(num_blocks, host_blocks),
          np.random.default_rng(seed), steps)


def test_offload_wider_than_host_pool_is_rejected():
    m = _TwoTierModel(12, 2)
    m.op_alloc(3)                 # 3 blocks > host capacity 2
    m.op_offload(0)
    m.check()
    assert m.host.num_entries == 0 and m.host.rejected == 1
    assert m.pool.in_use == 0     # rejected offload still frees the pages


def test_swap_in_survives_source_block_recycling():
    """The aliasing check in earnest: offload, recycle every freed block
    with new payloads, then swap in — the host copy must be pristine."""
    m = _TwoTierModel(8, 4)
    m.op_alloc(2)
    key_payloads = tuple(m.content[b] for b in m.tables[0])
    m.op_offload(0)
    m.op_alloc(3)                 # recycles + overwrites the freed blocks
    m.check()
    m.op_swap_in(0)               # asserts payload == snapshot inside
    m.check()
    got = tuple(m.content[b] for b in m.tables[-1])
    assert got == key_payloads
    m.drain()


def test_prefetch_stale_generation_discard():
    """A key re-offloaded with different pages after the transfer was
    issued must be swept as stale (generation mismatch), never resolved:
    the transfer belongs to a dead page lifetime even though the key is
    host-resident again."""
    m = _TwoTierModel(8, 4)
    m.op_alloc(2)
    m.op_offload(0)                      # entry-1, gens A
    key = sorted(m.expected)[0]
    m.op_prefetch_issue(0)
    assert key in m.prefetch
    old_gens = m.expected[key][1]
    # swap the entry back in (host copy consumed), then re-offload the
    # same logical key with recycled blocks -> new generations
    m.op_swap_in(0)
    payloads = tuple(m.content[b] for b in m.tables[0])
    gens = tuple((b, m.pool.generation(b)) for b in m.tables[0])
    k, v = m._pages(payloads)
    t = m.tables.pop(0)
    m.host.offload(key, k, v, first=7, gens=gens)
    m.expected[key] = (payloads, gens)
    m.pool.free(t)
    for b in t:
        del m.refs[b]
    assert gens != old_gens
    # model bookkeeping: the in-flight snapshot now disagrees with the
    # host entry, so the sweep must discard exactly it
    assert m.prefetch.sweep() == 1
    del m.inflight[key]
    assert key not in m.prefetch
    assert m.prefetch.discarded == 1
    m.check()
    m.drain()


def test_prefetch_resolve_mid_flight_is_bounded_wait():
    """Taking a transfer immediately after issue (the in-flight-wait
    path) still yields the exact snapshot: JAX sequences the read after
    the async copy, so an early consumer waits, never corrupts."""
    m = _TwoTierModel(8, 4)
    m.op_alloc(3)
    m.op_offload(0)
    m.op_prefetch_issue(0)
    m.op_prefetch_resolve(0)     # asserts payload == snapshot inside
    m.check()
    m.drain()


# ---------------------------------------------------------------------------
# hypothesis rule-based state machine
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    class TwoTierMachine(RuleBasedStateMachine):
        @initialize(num_blocks=st.integers(3, 24),
                    host_blocks=st.integers(0, 6))
        def init_pools(self, num_blocks, host_blocks):
            self.model = _TwoTierModel(num_blocks, host_blocks)

        @rule(n=st.integers(1, 4))
        def alloc(self, n):
            self.model.op_alloc(n)

        @rule(i=st.integers(0, 1 << 16))
        def share(self, i):
            self.model.op_share(i)

        @rule(i=st.integers(0, 1 << 16), j=st.integers(0, 1 << 16))
        def cow(self, i, j):
            self.model.op_cow(i, j)

        @rule(i=st.integers(0, 1 << 16))
        def release(self, i):
            self.model.op_release(i)

        @rule(i=st.integers(0, 1 << 16))
        def offload(self, i):
            self.model.op_offload(i)

        @rule(i=st.integers(0, 1 << 16))
        def swap_in(self, i):
            self.model.op_swap_in(i)

        @rule(i=st.integers(0, 1 << 16))
        def prefetch_issue(self, i):
            self.model.op_prefetch_issue(i)

        @rule(i=st.integers(0, 1 << 16))
        def prefetch_resolve(self, i):
            self.model.op_prefetch_resolve(i)

        @rule()
        def prefetch_sweep(self):
            self.model.op_prefetch_sweep()

        @rule(b=st.integers(0, 1 << 16))
        def bad_calls(self, b):
            self.model.op_bad_calls(b)

        @invariant()
        def invariants_hold(self):
            if hasattr(self, "model"):
                self.model.check()

    TwoTierMachine.TestCase.settings = settings(
        max_examples=30, stateful_step_count=40, deadline=None)
    TestTwoTierStateMachine = TwoTierMachine.TestCase
