"""Stateful property suite for the two-tier KV block pool.

A single model (:class:`_TwoTierModel`) drives random interleavings of
alloc / incref (prefix share) / CoW / free / offload / swap-in against a
real ``BlockPool`` + ``HostBlockPool`` pair, shadowing them with pure
Python bookkeeping, and checks after every step:

  * refcount conservation — the pool's refcounts equal the model's for
    every block, and free + live == capacity (free-list integrity);
  * no double-free / no incref-of-free — both raise, and a freed block
    only ever returns to the free list once;
  * no device/host page aliasing — a host entry is a verbatim *copy*:
    its payload still equals the offload-time snapshot after the source
    blocks were recycled and overwritten, and the generation tags prove
    it (a source block whose generation is unchanged since offload must
    still be free; any reuse bumped it);
  * host-tier integrity — block accounting matches the entries, capacity
    is never exceeded, eviction is LRU.

The hypothesis rule-based state machine explores random interleavings
when hypothesis is installed; the deterministic fallback walks (seeded
rng over the same model) always run.
"""
import numpy as np
import pytest

from repro.kvcache.paged import BlockPool, HostBlockPool, PoolExhausted

try:
    from hypothesis import settings
    from hypothesis.stateful import (RuleBasedStateMachine, initialize,
                                     invariant, rule)
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


class _TwoTierModel:
    """Shadow model + operation vocabulary shared by the hypothesis state
    machine and the deterministic fallback walks."""

    def __init__(self, num_blocks: int, host_blocks: int):
        self.pool = BlockPool(num_blocks)
        self.host = HostBlockPool(host_blocks)
        self.tables = []          # live mappings: lists of block ids
        self.refs = {}            # block -> model refcount
        self.content = {}         # block -> payload currently on "device"
        self.expected = {}        # host key -> (payloads, gens) snapshot
        self._payload = 0.0
        self._key = 0

    # -- helpers ---------------------------------------------------------
    def _fresh_payload(self) -> float:
        self._payload += 1.0
        return self._payload

    def _pages(self, payloads):
        """Fake (L, nb, bs, KH, D) device pages holding one payload per
        block — enough to detect any bit of aliasing or reordering."""
        arr = np.asarray(payloads, np.float32).reshape(1, -1, 1, 1, 1)
        return arr, arr + 0.5

    # -- operations ------------------------------------------------------
    def op_alloc(self, n: int):
        gens_before = {b: self.pool.generation(b)
                       for b in range(self.pool.num_blocks)}
        try:
            ids = self.pool.alloc(n)
        except PoolExhausted:
            assert self.pool.available < n
            return
        assert len(set(ids)) == n
        for b in ids:
            # every hand-out bumps the block's generation exactly once
            assert self.pool.generation(b) == gens_before[b] + 1
            assert b not in self.refs, "allocated a live block"
            self.refs[b] = 1
            self.content[b] = self._fresh_payload()
        self.tables.append(list(ids))

    def op_share(self, i: int):
        if not self.tables:
            return
        t = self.tables[i % len(self.tables)]
        self.pool.incref(t)
        for b in t:
            self.refs[b] += 1
        self.tables.append(list(t))

    def op_cow(self, i: int, j: int):
        if not self.tables:
            return
        t = self.tables[i % len(self.tables)]
        b = t[j % len(t)]
        if not self.pool.needs_copy(b):
            return
        try:
            new = self.pool.alloc(1)[0]
        except PoolExhausted:
            return
        self.refs[new] = 1
        self.content[new] = self.content[b]
        self.pool.free([b])
        self.refs[b] -= 1
        t[t.index(b)] = new

    def op_release(self, i: int):
        if not self.tables:
            return
        t = self.tables.pop(i % len(self.tables))
        self.pool.free(t)
        for b in t:
            self.refs[b] -= 1
            if self.refs[b] == 0:
                del self.refs[b]

    def op_offload(self, i: int):
        """Evict a cold mapping (all blocks refcount 1, like a prefix
        entry owned only by the cache) through the host tier."""
        if not self.tables:
            return
        i %= len(self.tables)
        t = self.tables[i]
        if any(self.refs[b] != 1 for b in t):
            return
        payloads = tuple(self.content[b] for b in t)
        gens = tuple((b, self.pool.generation(b)) for b in t)
        k, v = self._pages(payloads)
        self._key += 1
        key = f"entry-{self._key}"
        stored_before = key in self.host
        assert not stored_before
        evicted = self.host.offload(key, k, v, first=7, gens=gens)
        for ek in evicted:
            del self.expected[ek]
        if key in self.host:
            self.expected[key] = (payloads, gens)
        else:                      # wider than the whole host pool
            assert len(t) > self.host.capacity_blocks or \
                self.host.capacity_blocks == 0
        self.tables.pop(i)
        self.pool.free(t)
        for b in t:
            del self.refs[b]

    def op_swap_in(self, i: int):
        if not self.expected:
            return
        key = sorted(self.expected)[i % len(self.expected)]
        payloads, gens = self.expected[key]
        if self.pool.available < len(payloads):
            return
        entry = self.host.fetch(key)
        assert entry is not None
        del self.expected[key]
        # no aliasing: the host copy still equals the offload-time
        # snapshot, regardless of what happened to the source blocks
        got = np.asarray(entry["k"]).reshape(-1)
        np.testing.assert_array_equal(got, np.asarray(payloads, np.float32))
        np.testing.assert_array_equal(np.asarray(entry["v"]).reshape(-1),
                                      got + 0.5)
        assert entry["gens"] == gens
        for b, g in gens:
            # an unchanged generation means the source block was never
            # reused since offload — it must still be on the free list
            if self.pool.generation(b) == g:
                assert self.pool.is_free(b), \
                    f"block {b} live with stale generation {g}"
            else:
                assert self.pool.generation(b) > g
        ids = self.pool.alloc(len(payloads))
        for b, p in zip(ids, payloads):
            self.refs[b] = 1
            self.content[b] = p
        self.tables.append(list(ids))

    def op_bad_calls(self, b: int):
        """Double-free and incref-of-free must raise and mutate nothing."""
        b = 1 + (b % (self.pool.num_blocks - 1))
        if not self.pool.is_free(b):
            return
        before = self.pool.available
        with pytest.raises(ValueError):
            self.pool.free([b])
        with pytest.raises(ValueError):
            self.pool.incref([b])
        assert self.pool.available == before

    # -- invariants ------------------------------------------------------
    def check(self):
        self.pool.check_invariants()
        self.host.check_invariants()
        for b in range(1, self.pool.num_blocks):
            assert self.pool.refcount(b) == self.refs.get(b, 0), \
                f"refcount drift on block {b}"
        assert set(self.host.keys()) == set(self.expected)
        assert self.host.used_blocks == \
            sum(len(p) for p, _ in self.expected.values())

    def drain(self):
        while self.tables:
            self.op_release(0)
        self.check()
        assert self.pool.in_use == 0
        assert self.pool.available == self.pool.capacity


_OPS = ("alloc", "share", "cow", "release", "offload", "swap_in", "bad")


def _walk(model: _TwoTierModel, rng, steps: int):
    for _ in range(steps):
        op = _OPS[rng.integers(0, len(_OPS))]
        i = int(rng.integers(0, 1 << 16))
        if op == "alloc":
            model.op_alloc(int(rng.integers(1, 4)))
        elif op == "share":
            model.op_share(i)
        elif op == "cow":
            model.op_cow(i, int(rng.integers(0, 1 << 16)))
        elif op == "release":
            model.op_release(i)
        elif op == "offload":
            model.op_offload(i)
        elif op == "swap_in":
            model.op_swap_in(i)
        else:
            model.op_bad_calls(i)
        model.check()
    model.drain()


# ---------------------------------------------------------------------------
# deterministic fallback walks (always run)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed,num_blocks,host_blocks,steps", [
    (0, 16, 4, 300),
    (1, 6, 2, 250),      # tight device pool: exhaustion paths
    (2, 12, 0, 200),     # host tier disabled: offload degrades to drop
    (3, 8, 1, 250),      # one-block host tier: constant LRU churn
])
def test_two_tier_deterministic_walk(seed, num_blocks, host_blocks, steps):
    _walk(_TwoTierModel(num_blocks, host_blocks),
          np.random.default_rng(seed), steps)


def test_offload_wider_than_host_pool_is_rejected():
    m = _TwoTierModel(12, 2)
    m.op_alloc(3)                 # 3 blocks > host capacity 2
    m.op_offload(0)
    m.check()
    assert m.host.num_entries == 0 and m.host.rejected == 1
    assert m.pool.in_use == 0     # rejected offload still frees the pages


def test_swap_in_survives_source_block_recycling():
    """The aliasing check in earnest: offload, recycle every freed block
    with new payloads, then swap in — the host copy must be pristine."""
    m = _TwoTierModel(8, 4)
    m.op_alloc(2)
    key_payloads = tuple(m.content[b] for b in m.tables[0])
    m.op_offload(0)
    m.op_alloc(3)                 # recycles + overwrites the freed blocks
    m.check()
    m.op_swap_in(0)               # asserts payload == snapshot inside
    m.check()
    got = tuple(m.content[b] for b in m.tables[-1])
    assert got == key_payloads
    m.drain()


# ---------------------------------------------------------------------------
# hypothesis rule-based state machine
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    class TwoTierMachine(RuleBasedStateMachine):
        @initialize(num_blocks=st.integers(3, 24),
                    host_blocks=st.integers(0, 6))
        def init_pools(self, num_blocks, host_blocks):
            self.model = _TwoTierModel(num_blocks, host_blocks)

        @rule(n=st.integers(1, 4))
        def alloc(self, n):
            self.model.op_alloc(n)

        @rule(i=st.integers(0, 1 << 16))
        def share(self, i):
            self.model.op_share(i)

        @rule(i=st.integers(0, 1 << 16), j=st.integers(0, 1 << 16))
        def cow(self, i, j):
            self.model.op_cow(i, j)

        @rule(i=st.integers(0, 1 << 16))
        def release(self, i):
            self.model.op_release(i)

        @rule(i=st.integers(0, 1 << 16))
        def offload(self, i):
            self.model.op_offload(i)

        @rule(i=st.integers(0, 1 << 16))
        def swap_in(self, i):
            self.model.op_swap_in(i)

        @rule(b=st.integers(0, 1 << 16))
        def bad_calls(self, b):
            self.model.op_bad_calls(b)

        @invariant()
        def invariants_hold(self):
            if hasattr(self, "model"):
                self.model.check()

    TwoTierMachine.TestCase.settings = settings(
        max_examples=30, stateful_step_count=40, deadline=None)
    TestTwoTierStateMachine = TwoTierMachine.TestCase
