"""Paged KV-cache subsystem unit/property tests.

BlockPool invariants under arbitrary alloc/incref/free interleavings
(hypothesis when installed; deterministic fallbacks always run):
  * no double-allocation — a live block never reappears in the free list
  * conservation — free + live == capacity after every operation
  * refcounts never drop below 1 while live; double free raises

Device ops: write_blocks/gather_layer round-trip is exactly the slotted
cache contents; append_layer lands tokens at (table[b, len//bs], len%bs);
copy_block duplicates a page bit-for-bit; NULL-page garbage lanes never
touch live pages.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.kvcache.block_table import (NULL_BLOCK, SlotTables, blocks_for,
                                       validate_block_size)
from repro.kvcache.paged import (BlockPool, PoolExhausted, append_layer,
                                 copy_block, gather_layer,
                                 grow_paged_kv_cache, init_paged_kv_cache,
                                 write_blocks)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="hypothesis not installed "
    "(pip install -r requirements-dev.txt)")


# ---------------------------------------------------------------------------
# allocator
# ---------------------------------------------------------------------------

def _random_walk(pool: BlockPool, rng, steps: int):
    """alloc/incref/free walk mirroring engine usage; invariants checked
    after every operation."""
    tables = []            # simulated block tables: lists of live ids
    for _ in range(steps):
        op = rng.integers(0, 4)
        if op == 0:                                    # admit
            n = int(rng.integers(1, 4))
            try:
                ids = pool.alloc(n)
            except PoolExhausted:
                assert pool.available < n
            else:
                tables.append(ids)
        elif op == 1 and tables:                       # prefix share
            src = tables[rng.integers(0, len(tables))]
            pool.incref(src)
            tables.append(list(src))
        elif op == 2 and tables:                       # release
            t = tables.pop(rng.integers(0, len(tables)))
            pool.free(t)
        elif op == 3 and tables:                       # CoW one block
            t = tables[rng.integers(0, len(tables))]
            bi = rng.integers(0, len(t))
            if pool.needs_copy(t[bi]):
                try:
                    new = pool.alloc(1)[0]
                except PoolExhausted:
                    continue
                pool.free([t[bi]])
                t[bi] = new
        pool.check_invariants()
    for t in tables:
        pool.free(t)
    pool.check_invariants()
    assert pool.in_use == 0 and pool.available == pool.capacity


def test_block_pool_deterministic_walk():
    _random_walk(BlockPool(16), np.random.default_rng(0), 300)


def test_block_pool_small_pool_walk():
    _random_walk(BlockPool(3), np.random.default_rng(1), 200)


if HAVE_HYPOTHESIS:
    @needs_hypothesis
    @given(st.integers(2, 32), st.integers(0, 2**31 - 1))
    @settings(max_examples=40, deadline=None)
    def test_block_pool_property_walk(num_blocks, seed):
        _random_walk(BlockPool(num_blocks), np.random.default_rng(seed), 150)


def test_block_pool_basics():
    pool = BlockPool(8)
    assert pool.capacity == 7
    ids = pool.alloc(3)
    assert NULL_BLOCK not in ids and len(set(ids)) == 3
    assert pool.in_use == 3 and pool.available == 4
    # exhaustion allocates nothing
    with pytest.raises(PoolExhausted):
        pool.alloc(5)
    assert pool.available == 4
    pool.check_invariants()
    # refcounting: share then free once keeps the block live
    pool.incref(ids)
    assert all(pool.refcount(b) == 2 for b in ids)
    assert pool.free(ids) == 0
    assert pool.free(ids) == 3
    with pytest.raises(ValueError):
        pool.free([ids[0]])          # double free
    with pytest.raises(ValueError):
        pool.incref([ids[0]])        # incref of a free block
    pool.check_invariants()


def test_block_pool_grow_preserves_live_blocks():
    pool = BlockPool(4)
    ids = pool.alloc(3)
    pool.grow(10)
    pool.check_invariants()
    assert pool.capacity == 9 and pool.available == 6
    assert all(pool.refcount(b) == 1 for b in ids)


def test_blocks_for_and_validate():
    assert blocks_for(0, 16) == 0
    assert blocks_for(1, 16) == 1
    assert blocks_for(16, 16) == 1
    assert blocks_for(17, 16) == 2
    validate_block_size(16, 64)
    with pytest.raises(ValueError):
        validate_block_size(24, 64)   # does not divide
    with pytest.raises(ValueError):
        validate_block_size(0, 64)


# ---------------------------------------------------------------------------
# slot tables
# ---------------------------------------------------------------------------

def test_slot_tables_lifecycle():
    t = SlotTables(2, 4, block_size=16)
    t.assign(0, [3, 5], length=20, offset=7)
    assert t.slot_blocks(0) == [3, 5]
    assert t.length[0] == 20 and t.offset[0] == 7
    t.append_block(0, 9)
    assert t.slot_blocks(0) == [3, 5, 9]
    t.replace_block(0, 1, 6)          # CoW swap
    assert t.slot_blocks(0) == [3, 6, 9]
    # ticks mirror the slotted decode's length+1 for every slot
    t.tick()
    assert t.length[0] == 21 and t.length[1] == 1
    ids = t.clear(0)
    assert ids == [3, 6, 9]
    assert np.all(t.table[0] == NULL_BLOCK)
    # stale length survives clear (garbage-lane bit-parity with slotted)
    assert t.length[0] == 21
    t.grow(6)
    assert t.blocks_per_slot == 6
    with pytest.raises(ValueError):
        t.assign(1, list(range(7)), 10, 0)


# ---------------------------------------------------------------------------
# device data path
# ---------------------------------------------------------------------------

def _pool_fixture(L=2, N=8, bs=4, KH=2, D=8):
    return init_paged_kv_cache(L, N, bs, KH, D, jnp.float32)


def test_write_gather_roundtrip_matches_contiguous():
    L, bs, KH, D = 2, 4, 2, 8
    pool = _pool_fixture(L=L, bs=bs, KH=KH, D=D)
    rng = np.random.default_rng(0)
    true_len = 10
    S = 12                             # 3 blocks
    k = rng.normal(size=(L, S, KH, D)).astype(np.float32)
    v = rng.normal(size=(L, S, KH, D)).astype(np.float32)
    ids = jnp.asarray([3, 1, 5], jnp.int32)
    pool = write_blocks(pool, ids, jnp.asarray(k), jnp.asarray(v),
                        true_len=true_len)
    table = jnp.asarray([[3, 1, 5]], jnp.int32)
    got_k = np.asarray(gather_layer(pool.k[0], table))[0]   # (S, KH, D)
    ref = k[0].copy()
    ref[true_len:] = 0.0               # pad guard zeroes bucket garbage
    np.testing.assert_array_equal(got_k, ref)
    # pages not named by block_ids stay zero
    untouched = [b for b in range(8) if b not in (3, 1, 5)]
    assert np.all(np.asarray(pool.k[:, untouched]) == 0.0)


def test_append_layer_scatter_and_null_sink():
    bs, KH, D = 4, 2, 8
    pool_layer = jnp.zeros((6, bs, KH, D), jnp.float32)
    table = jnp.asarray([[2, 3], [NULL_BLOCK, NULL_BLOCK]], jnp.int32)
    lengths = jnp.asarray([5, 9], jnp.int32)   # slot1 inactive garbage lane
    new = jnp.ones((2, KH, D), jnp.float32) * jnp.asarray(
        [[[1.0]], [[7.0]]])
    out = append_layer(pool_layer, new, table, lengths)
    # slot0: token 5 -> block idx 1 (page 3), offset 1
    np.testing.assert_array_equal(np.asarray(out[3, 1]), np.ones((KH, D)))
    # slot1's garbage landed in the null page, nowhere else
    live = np.asarray(out[np.asarray([1, 2, 4, 5])])
    assert np.all(live[live != 0] == 1.0)
    assert np.all(np.asarray(out[NULL_BLOCK, 9 % bs]) == 7.0)


def test_copy_block_bitwise_and_grow_preserves_pages():
    pool = _pool_fixture()
    rng = np.random.default_rng(1)
    k = rng.normal(size=(2, 4, 2, 8)).astype(np.float32)
    v = rng.normal(size=(2, 4, 2, 8)).astype(np.float32)
    pool = write_blocks(pool, jnp.asarray([2], jnp.int32),
                        jnp.asarray(k), jnp.asarray(v))
    pool = copy_block(pool, 6, 2)
    np.testing.assert_array_equal(np.asarray(pool.k[:, 6]),
                                  np.asarray(pool.k[:, 2]))
    grown = grow_paged_kv_cache(pool, 12)
    assert grown.num_blocks == 12
    np.testing.assert_array_equal(np.asarray(grown.k[:, :8]),
                                  np.asarray(pool.k))
    assert np.all(np.asarray(grown.k[:, 8:]) == 0.0)
