"""Per-kernel validation: shape/dtype sweeps, interpret-mode kernel vs the
pure-jnp oracle in repro.kernels.ref (assignment requirement (c))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels import ref as kref

KEY = jax.random.PRNGKey(0)


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


def _tols(dtype):
    return dict(rtol=2e-5, atol=2e-5) if dtype == jnp.float32 else \
        dict(rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("E,cap,H,KH,D,C,blk", [
    (3, 8, 4, 2, 32, 64, 16),
    (2, 16, 8, 8, 64, 128, 128),
    (1, 4, 2, 1, 16, 32, 32),
    (4, 8, 6, 2, 64, 48, 16),     # ragged C vs blk
    (2, 8, 4, 4, 128, 256, 512),  # blk > C
])
def test_shared_chunk_attention(dtype, E, cap, H, KH, D, C, blk):
    qd = _rand(jax.random.fold_in(KEY, 1), (E, cap, H, D), dtype)
    k = _rand(jax.random.fold_in(KEY, 2), (E, C, KH, D), dtype)
    v = _rand(jax.random.fold_in(KEY, 3), (E, C, KH, D), dtype)
    qm = jax.random.bernoulli(jax.random.fold_in(KEY, 4), 0.7, (E, cap))
    o1, l1 = ops.shared_chunk_attention(qd, k, v, qm, block_c=blk)
    o2, l2 = kref.shared_chunk_attention_ref(qd, k, v, qm)
    np.testing.assert_allclose(np.float32(o1), np.float32(o2),
                               **_tols(dtype))
    np.testing.assert_allclose(l1, l2, rtol=2e-2 if dtype == jnp.bfloat16
                               else 2e-5, atol=2e-2)
    # masked slots must carry -inf lse and zero output
    assert np.all(np.asarray(l1)[~np.asarray(qm)] < -1e29)
    assert np.all(np.float32(o1)[~np.asarray(qm)] == 0.0)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,KH,D,S,blk", [
    (4, 8, 2, 32, 100, 32),
    (2, 4, 4, 64, 256, 256),
    (3, 2, 1, 16, 33, 16),
    (1, 16, 8, 128, 512, 128),
])
def test_decode_attention(dtype, B, H, KH, D, S, blk):
    q = _rand(jax.random.fold_in(KEY, 1), (B, H, D), dtype)
    k = _rand(jax.random.fold_in(KEY, 2), (B, S, KH, D), dtype)
    v = _rand(jax.random.fold_in(KEY, 3), (B, S, KH, D), dtype)
    lens = jax.random.randint(jax.random.fold_in(KEY, 4), (B,), 1, S + 1)
    o1, l1 = ops.decode_attention(q, k, v, lens, block_s=blk)
    o2, l2 = kref.decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(np.float32(o1), np.float32(o2),
                               **_tols(dtype))
    np.testing.assert_allclose(l1, l2, rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,KH,D,N,bs,M", [
    (3, 8, 2, 32, 16, 16, 4),
    (2, 4, 4, 64, 9, 32, 3),
    (1, 16, 8, 128, 32, 8, 8),
])
def test_paged_decode_attention(dtype, B, H, KH, D, N, bs, M):
    """Scalar-prefetch paged kernel vs the gather oracle, and the oracle
    vs the dense reference on an equivalently-filled contiguous cache
    (bitwise — the engine's paged/slotted bit-identity rests on it)."""
    from repro.kernels.paged_decode_attn import paged_decode_attention_ref
    q = _rand(jax.random.fold_in(KEY, 11), (B, H, D), dtype)
    k_pool = _rand(jax.random.fold_in(KEY, 12), (N, bs, KH, D), dtype)
    v_pool = _rand(jax.random.fold_in(KEY, 13), (N, bs, KH, D), dtype)
    # distinct non-null pages per slot, scrambled order
    perm = jax.random.permutation(jax.random.fold_in(KEY, 14),
                                  jnp.arange(1, N))[:B * M]
    table = perm.reshape(B, M).astype(jnp.int32)
    lens = jax.random.randint(jax.random.fold_in(KEY, 15), (B,), 1,
                              M * bs + 1)
    o1, l1 = ops.paged_decode_attention(q, k_pool, v_pool, table, lens)
    o2, l2 = paged_decode_attention_ref(q, k_pool, v_pool, table, lens)
    np.testing.assert_allclose(np.float32(o1), np.float32(o2),
                               **_tols(dtype))
    np.testing.assert_allclose(l1, l2, rtol=2e-2, atol=2e-2)
    # oracle == dense ref, bit for bit, on the gathered contiguous cache
    from repro.kvcache.paged import gather_layer
    kc = gather_layer(k_pool, table)
    vc = gather_layer(v_pool, table)
    o3, l3 = kref.decode_attention_ref(q, kc, vc, lens)
    np.testing.assert_array_equal(np.asarray(o2), np.asarray(o3))
    np.testing.assert_array_equal(np.asarray(l2), np.asarray(l3))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("P,N,H,D,blk", [
    (2, 64, 4, 32, 16), (3, 7, 2, 16, 8), (4, 128, 8, 64, 128),
])
def test_lse_merge(dtype, P, N, H, D, blk):
    outs = _rand(jax.random.fold_in(KEY, 5), (P, N, H, D), dtype)
    lses = jax.random.normal(jax.random.fold_in(KEY, 6), (P, N, H)) * 3
    o1, l1 = ops.lse_merge(outs, lses, block_n=blk)
    o2, l2 = kref.lse_merge_ref(outs, lses)
    np.testing.assert_allclose(np.float32(o1), np.float32(o2),
                               **_tols(dtype))
    np.testing.assert_allclose(l1, l2, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("G,H,KH,D,E,bg,be", [
    (8, 8, 2, 32, 16, 4, 4),
    (5, 4, 4, 16, 7, 8, 8),
    (128, 8, 8, 64, 512, 128, 512),
])
def test_router_scores(G, H, KH, D, E, bg, be):
    q = jax.random.normal(jax.random.fold_in(KEY, 7), (G, H, D))
    emb = jax.random.normal(jax.random.fold_in(KEY, 8), (E, KH, D))
    s1 = ops.router_scores(q, emb, block_g=bg, block_e=be)
    s2 = kref.router_scores_ref(q, emb)
    np.testing.assert_allclose(s1, s2, rtol=2e-5, atol=2e-5)


def test_merge_of_decode_splits_equals_joint():
    """Flash-decoding invariant: decode over split caches + lse_merge ==
    decode over the whole cache (the disaggregated combine is exact)."""
    B, H, KH, D, S = 3, 8, 2, 32, 128
    q = _rand(jax.random.fold_in(KEY, 1), (B, H, D), jnp.float32)
    k = _rand(jax.random.fold_in(KEY, 2), (B, S, KH, D), jnp.float32)
    v = _rand(jax.random.fold_in(KEY, 3), (B, S, KH, D), jnp.float32)
    full = jnp.full((B,), S, jnp.int32)
    oj, _ = ops.decode_attention(q, k, v, full)
    half = jnp.full((B,), S // 2, jnp.int32)
    o1, l1 = ops.decode_attention(q, k[:, :S // 2], v[:, :S // 2], half)
    o2, l2 = ops.decode_attention(q, k[:, S // 2:], v[:, S // 2:], half)
    om, _ = ops.lse_merge(jnp.stack([o1, o2]), jnp.stack([l1, l2]))
    np.testing.assert_allclose(np.float32(om), np.float32(oj),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("E,cap,H,KH,D,C,blk", [
    (3, 8, 4, 2, 32, 64, 16), (2, 8, 8, 8, 64, 96, 64),
])
def test_shared_chunk_attention_int8(E, cap, H, KH, D, C, blk):
    """int8-quantized store kernel (in-register dequant) vs dequantized
    oracle, and bounded quantization error vs the fp reference."""
    from repro.core.shared_kv import _quantize
    from repro.kernels.shared_chunk_attn import shared_chunk_attention_q8
    qd = _rand(jax.random.fold_in(KEY, 1), (E, cap, H, D), jnp.float32)
    k = _rand(jax.random.fold_in(KEY, 2), (E, C, KH, D), jnp.float32)
    v = _rand(jax.random.fold_in(KEY, 3), (E, C, KH, D), jnp.float32)
    qm = jnp.ones((E, cap), bool)
    kq, ks = _quantize(k)
    vq, vs = _quantize(v)
    o1, l1 = shared_chunk_attention_q8(qd, kq, vq, ks, vs, qm, block_c=blk)
    kd = kq.astype(jnp.float32) * ks[..., None]
    vd = vq.astype(jnp.float32) * vs[..., None]
    o2, l2 = kref.shared_chunk_attention_ref(qd, kd, vd, qm)
    np.testing.assert_allclose(np.float32(o1), np.float32(o2),
                               rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(l1, l2, rtol=1e-3, atol=1e-3)
    o3, _ = kref.shared_chunk_attention_ref(qd, k, v, qm)
    assert float(jnp.max(jnp.abs(np.float32(o1) - o3))) < 0.05


def test_int8_store_end_to_end():
    """Dense decode with a quantized store ~= decode with the fp store."""
    import dataclasses
    from repro.configs import get_config
    from repro.core.shared_kv import build_store
    from repro.kvcache import init_kv_cache
    from repro.models import dense
    cfg = dataclasses.replace(get_config("tinyllama-1.1b").reduced(),
                              dtype="float32")
    params = dense.init_params(cfg, KEY)
    B, CL = 2, 128
    ctoks = jax.random.randint(jax.random.fold_in(KEY, 5), (1, CL), 0,
                               cfg.vocab_size)
    ccache = init_kv_cache(cfg.num_layers, 1, CL, cfg.num_kv_heads,
                           cfg.head_dim, jnp.float32)
    _, ccache = dense.prefill(cfg, params, ctoks, ccache)
    s_fp = build_store(ccache.k[:, 0], ccache.v[:, 0], cfg.moska.chunk_size)
    s_q8 = build_store(ccache.k[:, 0], ccache.v[:, 0], cfg.moska.chunk_size,
                       quantize=True)
    assert s_q8.quantized and s_q8.k.dtype == jnp.int8
    toks = jax.random.randint(jax.random.fold_in(KEY, 6), (B, 8), 0,
                              cfg.vocab_size)
    c1 = init_kv_cache(cfg.num_layers, B, 12, cfg.num_kv_heads,
                       cfg.head_dim, jnp.float32)
    _, c1 = dense.prefill(cfg, params, toks, c1, store=s_fp, start_pos=CL)
    l_fp, _ = dense.decode_step(cfg, params, toks[:, -1], c1, store=s_fp)
    l_q8, _ = dense.decode_step(cfg, params, toks[:, -1], c1, store=s_q8)
    np.testing.assert_allclose(np.asarray(l_fp), np.asarray(l_q8),
                               rtol=0.1, atol=0.1)
