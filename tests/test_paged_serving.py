"""Paged-vs-slotted serving differentials.

The paged KV layout's contract: *identical generations* to the slotted
layout (the gather view tiles max_seq and masked positions carry
exactly-zero probability, so the attention program is the same), while
admitting strictly more concurrent requests under the same memory budget
(block-granular accounting) and serving prompts past max_seq (chunked
prefill). Prefix sharing must stay invisible to outputs (copy-on-write).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.configs import get_config
from repro.core.scheduler import Scheduler, SchedulerConfig
from repro.data.pipeline import CorpusSpec, synthesize_corpus
from repro.models.model import build_model
from repro.serving.engine import (EngineConfig, ServingEngine,
                                  _merge_slot_cache)

KEY = jax.random.PRNGKey(0)

_STATE = {}


def _setup():
    if not _STATE:
        cfg = get_config("tinyllama-1.1b").reduced()
        model = build_model(cfg)
        _STATE["cfg"] = cfg
        _STATE["params"] = model.init(KEY)
        _STATE["corpus"] = synthesize_corpus(
            CorpusSpec("laws", 256, cfg.vocab_size))
    return _STATE["cfg"], _STATE["params"], _STATE["corpus"]


def _generate(layout, prompts, max_new=4, corpus=True, **kw):
    cfg, params, corpus_toks = _setup()
    obs.reset_registry()
    eng = ServingEngine(cfg, params,
                        EngineConfig(max_slots=3, max_seq=64,
                                     kv_layout=layout, **kw))
    cid = None
    if corpus:
        eng.register_corpus("laws", corpus_toks)
        cid = "laws"
    for p in prompts:
        eng.submit(p, max_new_tokens=max_new, corpus_id=cid)
    done = eng.run()
    gens = {r.uid: tuple(r.generated) for r in done}
    return gens, obs.get_registry().snapshot(), eng


def test_paged_bit_identical_to_slotted():
    # ragged lengths + a duplicate prompt (prefix-cache + CoW path) —
    # the full admission/decode/release lifecycle must not perturb a
    # single logit
    prompts = [[1 + i] * (5 + 3 * i) for i in range(5)] + [[1] * 5]
    slotted, ssnap, _ = _generate("slotted", prompts)
    paged, psnap, _ = _generate("paged", prompts, block_size=16,
                                num_blocks=64)
    assert slotted == paged
    assert psnap["kvcache/prefix_hits"]["value"] >= 1
    assert psnap["kvcache/cow_copies"]["value"] >= 1
    assert psnap["kvcache/blocks_shared"]["value"] >= 1


def test_paged_high_water_below_slotted():
    # skewed mix: one long prompt, several short ones — the slotted slab
    # pays max_seq per slot, the paged pool only the blocks actually used
    # the 15-token prompt crosses a page boundary while decoding, so the
    # on-demand append path runs
    prompts = [[2] * 40, [3] * 15] + [[4 + i] * 6 for i in range(3)]
    slotted, ssnap, _ = _generate("slotted", prompts)
    paged, psnap, _ = _generate("paged", prompts, block_size=16)
    assert slotted == paged
    s_hw = ssnap["engine/hbm_high_water_bytes"]["value"]
    p_hw = psnap["engine/hbm_high_water_bytes"]["value"]
    assert p_hw <= s_hw
    assert psnap["kvcache/blocks_appended"]["value"] >= 1


def test_paged_admits_more_under_equal_budget():
    # scheduler-level: same budget, same skewed queue; block accounting
    # admits strictly more concurrent requests than slot accounting
    def mk(layout):
        s = Scheduler(SchedulerConfig(
            max_slots=8, mem_budget_bytes=3 * 64 * 128,
            unique_bytes_per_token=128, max_seq=64,
            kv_layout=layout, block_size=16))
        for _ in range(8):
            s.submit([1] * 6, 4, corpus_id="c0")   # 10 tokens = 1 block
        return len(s.schedule())
    n_slotted = mk("slotted")
    n_paged = mk("paged")
    assert n_slotted == 3                # budget fits 3 full slots
    assert n_paged > n_slotted           # blocks: 8 requests fit easily
    assert n_paged == 8


def test_slotted_rejects_long_prompt_naming_paged():
    cfg, params, _ = _setup()
    eng = ServingEngine(cfg, params, EngineConfig(max_slots=2, max_seq=64))
    with pytest.raises(ValueError, match="paged"):
        eng.submit([3] * 70, 4)


def test_paged_serves_long_prompt_via_chunked_prefill():
    cfg, params, corpus = _setup()
    prompt = list(range(1, 201))         # > max_seq=64
    # reference: a slotted engine whose bucket actually fits the prompt
    ref_eng = ServingEngine(cfg, params,
                            EngineConfig(max_slots=2, max_seq=256))
    ref_eng.register_corpus("laws", corpus)
    ref_eng.submit(prompt, 4, corpus_id="laws")
    ref = ref_eng.run()[0].generated

    obs.reset_registry()
    eng = ServingEngine(cfg, params,
                        EngineConfig(max_slots=2, max_seq=64,
                                     kv_layout="paged", block_size=16))
    eng.register_corpus("laws", corpus)
    eng.submit(prompt, 4, corpus_id="laws")
    got = eng.run()[0].generated
    snap = obs.get_registry().snapshot()
    assert snap["engine/chunked_prefills"]["value"] == 1
    assert snap["engine/prefill_chunks"]["value"] == 2   # 200 tokens @ 128
    # chunked prefill is numerically equivalent (not bitwise: different
    # contraction shapes); greedy argmax agrees on this model
    assert got == ref


def test_paged_budget_admission_and_eviction_under_pressure():
    # a tight block budget defers admissions instead of over-committing,
    # and the run still drains with bit-identical outputs
    cfg, params, _ = _setup()
    prompts = [[1 + i] * 8 for i in range(5)]
    slotted, _, _ = _generate("slotted", prompts, corpus=False)
    budget = 2 * 16 * cfg.kv_bytes_per_token * 64  # ~2 slots' worth
    paged, psnap, eng = _generate("paged", prompts, corpus=False,
                                  block_size=16,
                                  mem_budget_bytes=budget,
                                  share_prefix_blocks=False)
    assert slotted == paged
    assert eng.scheduler.idle
    assert eng._block_pool.in_use == 0   # everything released


def test_store_lru_eviction_and_reload():
    cfg, params, _ = _setup()
    c0 = synthesize_corpus(CorpusSpec("c0", 128, cfg.vocab_size))
    c1 = synthesize_corpus(CorpusSpec("c1", 128, cfg.vocab_size))
    probe = ServingEngine(cfg, params, EngineConfig(max_slots=2, max_seq=64))
    probe.register_corpus("c0", c0)
    store_bytes = probe.scheduler.shared_bytes
    slot_bytes = cfg.kv_bytes_per_token * 64
    budget = store_bytes * 1.5 + 2 * slot_bytes  # one store fits, two don't

    obs.reset_registry()
    eng = ServingEngine(cfg, params,
                        EngineConfig(max_slots=2, max_seq=64,
                                     mem_budget_bytes=budget))
    eng.register_corpus("c0", c0)
    eng.register_corpus("c1", c1)
    eng.submit([1, 2, 3], 3, corpus_id="c0")
    first = eng.run()
    eng.submit([4, 5, 6], 3, corpus_id="c1")    # forces c0 out
    eng.run()
    eng.submit([1, 2, 3], 3, corpus_id="c0")    # c0 rebuilt from tokens
    done = eng.run()
    snap = obs.get_registry().snapshot()
    assert snap["scheduler/store_evictions"]["value"] >= 1
    assert snap["kvcache/store_reloads"]["value"] >= 1
    # the rebuilt store is deterministic: same prompt, same generation
    assert first[0].generated == done[2].generated
    assert eng.scheduler.shared_bytes <= budget


def test_write_slot_pytree_matches_merge_oracle():
    # the donated ssm/hybrid admission write must equal the legacy
    # full-copy merge on an (L, B, S, ...)-shaped state pytree
    cfg, params, _ = _setup()
    eng = ServingEngine(cfg, params,
                        EngineConfig(max_slots=3, max_seq=16,
                                     donate_cache=False))
    rng = np.random.default_rng(0)
    cache = {
        "state": jnp.asarray(rng.normal(size=(2, 3, 8, 4)), jnp.float32),
        "length": jnp.zeros((3,), jnp.int32),
    }
    slot_cache = {
        "state": jnp.asarray(rng.normal(size=(2, 1, 5, 4)), jnp.float32),
        "length": jnp.asarray([5], jnp.int32),
    }
    want = _merge_slot_cache(cache, slot_cache, 1)
    got = eng._write_slot_pytree(cache, slot_cache,
                                 jnp.asarray(1, jnp.int32))
    for k in cache:
        np.testing.assert_array_equal(np.asarray(want[k]),
                                      np.asarray(got[k]))


def test_paged_requires_dense_family_cache():
    scfg = get_config("mamba2-130m").reduced()
    smodel = build_model(scfg)
    sparams = smodel.init(jax.random.PRNGKey(1))
    with pytest.raises(NotImplementedError, match="slotted"):
        ServingEngine(scfg, sparams,
                      EngineConfig(max_slots=2, max_seq=64,
                                   kv_layout="paged"))


def test_paged_rejects_bad_block_size():
    cfg, params, _ = _setup()
    with pytest.raises(ValueError, match="divide"):
        ServingEngine(cfg, params,
                      EngineConfig(max_slots=2, max_seq=64,
                                   kv_layout="paged", block_size=24))
