"""MoSKA core invariants: routing, dispatch, batched-vs-gather equivalence,
exact LSE merging, end-to-end exactness under full routing, and
property tests on the system's invariants.

``hypothesis`` is optional: when installed (see requirements-dev.txt) the
randomized property tests run; without it they skip and the deterministic
fallback cases below keep the same invariants covered.
"""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # pragma: no cover - exercised on lean installs
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="hypothesis not installed "
    "(pip install -r requirements-dev.txt)")

from repro.configs import get_config
from repro.configs.base import MoSKAConfig
from repro.core import (MoskaLayerContext, Routing, build_store,
                        moska_decode_attention, route,
                        shared_attention_batched,
                        shared_attention_gather_ref)
from repro.core import router as router_lib
from repro.kvcache import init_kv_cache
from repro.models import dense
from repro.models import layers as L

KEY = jax.random.PRNGKey(0)


def _store(E=8, C=16, KH=2, D=32, layers=1, key=KEY):
    k = jax.random.normal(jax.random.fold_in(key, 1), (layers, E * C, KH, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (layers, E * C, KH, D))
    return build_store(k, v, C)


# ---------------------------------------------------------------------------
# routing & dispatch
# ---------------------------------------------------------------------------

def test_route_topk_sound():
    store = _store()
    q = jax.random.normal(jax.random.fold_in(KEY, 3), (6, 8, 32))
    r = route(q, store.emb[0], 3)
    assert r.chunk_ids.shape == (6, 3)
    # selected scores are the k largest of the full score row
    full = np.asarray(r.full_scores)
    for g in range(6):
        top = np.sort(full[g])[-3:][::-1]
        np.testing.assert_allclose(np.asarray(r.scores[g]), top, rtol=1e-6)


def _check_dispatch_plan_invariants(G, K, E, seed):
    """Dispatch positions are unique per chunk, in-capacity slots keep
    every (group, k) pair, and counts never exceed capacity."""
    K = min(K, E)
    ids = jax.random.randint(jax.random.PRNGKey(seed), (G, K), 0, E)
    cap = max(1, (G * K) // E)
    flat, pos, keep = router_lib.dispatch_plan(ids, E, cap)
    flat, pos, keep = map(np.asarray, (flat, pos, keep))
    # kept slots have unique (chunk, pos) and pos < capacity
    kept = [(c, p) for c, p, k in zip(flat, pos, keep) if k]
    assert len(set(kept)) == len(kept)
    assert all(p < cap for _, p in kept)
    # per-chunk kept count == min(capacity, total routed there)
    for e in range(E):
        total = int((flat == e).sum())
        kept_e = int(((flat == e) & keep).sum())
        assert kept_e == min(cap, total)


@pytest.mark.parametrize("G,K,E,seed", [
    (1, 1, 1, 0), (12, 4, 8, 1), (5, 3, 4, 7), (9, 2, 3, 11),
    (12, 1, 8, 2), (2, 4, 5, 13),
])
def test_dispatch_plan_invariants_cases(G, K, E, seed):
    """Deterministic fallback cases (always run, hypothesis or not)."""
    _check_dispatch_plan_invariants(G, K, E, seed)


if HAVE_HYPOTHESIS:
    @needs_hypothesis
    @given(st.integers(1, 12), st.integers(1, 4), st.integers(1, 8),
           st.integers(0, 2**31 - 1))
    @settings(max_examples=30, deadline=None)
    def test_dispatch_plan_invariants(G, K, E, seed):
        _check_dispatch_plan_invariants(G, K, E, seed)


def test_required_capacity_mxu_aligned():
    cap = router_lib.required_capacity(256, 8, 64, 2.0)
    assert cap % 8 == 0 and cap >= 256 * 8 / 64


# ---------------------------------------------------------------------------
# batched == gather == dense
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("G,Q,K", [(6, 1, 3), (4, 8, 2), (1, 4, 8)])
def test_batched_equals_gather(G, Q, K):
    store = _store()
    E = store.num_chunks
    K = min(K, E)
    q = jax.random.normal(jax.random.fold_in(KEY, 4), (G, Q, 8, 32))
    r = route(jnp.mean(q, axis=1), store.emb[0], K)
    b = shared_attention_batched(q, store.k[0], store.v[0], r,
                                 capacity=G * K)
    g = shared_attention_gather_ref(q, store.k[0], store.v[0], r)
    np.testing.assert_allclose(b.out, g.out, rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(b.lse, g.lse, rtol=3e-5, atol=3e-5)


def test_full_routing_equals_dense_attention():
    store = _store(E=4, C=8)
    q = jax.random.normal(jax.random.fold_in(KEY, 5), (5, 1, 8, 32))
    r = route(q[:, 0], store.emb[0], store.num_chunks)
    b = shared_attention_batched(q, store.k[0], store.v[0], r,
                                 capacity=5 * store.num_chunks)
    kf = store.k[0].reshape(-1, 2, 32)
    vf = store.v[0].reshape(-1, 2, 32)
    qg = q.reshape(5, 1, 2, 4, 32)
    s = jnp.einsum("gqkhd,skd->gqkhs", qg, kf) / math.sqrt(32)
    p = jax.nn.softmax(s, -1)
    o = jnp.einsum("gqkhs,skd->gqkhd", p, vf).reshape(5, 1, 8, 32)
    np.testing.assert_allclose(b.out, o, rtol=3e-5, atol=3e-5)


def test_capacity_drops_degrade_gracefully():
    """With capacity 1 per chunk, outputs stay finite and LSE marks drops."""
    store = _store()
    q = jax.random.normal(jax.random.fold_in(KEY, 6), (8, 1, 8, 32))
    r = route(q[:, 0], store.emb[0], 2)
    b = shared_attention_batched(q, store.k[0], store.v[0], r, capacity=1)
    assert np.isfinite(np.asarray(b.out)).all()


def _check_merge_exactness(G, K, seed):
    """Unique ⊕ shared LSE merge == softmax over the union of key sets."""
    key = jax.random.PRNGKey(seed)
    E, C, KH, D, H, S = 4, 8, 2, 16, 4, 12
    store = _store(E=E, C=C, KH=KH, D=D, key=key)
    K = min(K, E)
    q = jax.random.normal(jax.random.fold_in(key, 3), (G, H, D))
    kc = jax.random.normal(jax.random.fold_in(key, 4), (G, S, KH, D))
    vc = jax.random.normal(jax.random.fold_in(key, 5), (G, S, KH, D))
    lens = jax.random.randint(jax.random.fold_in(key, 6), (G,), 1, S + 1)
    r = route(q, store.emb[0], E)   # full routing => exact
    ctx = MoskaLayerContext(store.k[0], store.v[0], r)
    out = moska_decode_attention(q, kc, vc, lens, ctx,
                                 MoSKAConfig(top_k_chunks=E))
    for g in range(G):
        keys = jnp.concatenate([store.k[0].reshape(-1, KH, D),
                                kc[g, :lens[g]]], 0)
        vals = jnp.concatenate([store.v[0].reshape(-1, KH, D),
                                vc[g, :lens[g]]], 0)
        qg = q[g].reshape(KH, H // KH, D)
        s = jnp.einsum("khd,skd->khs", qg, keys) / math.sqrt(D)
        p = jax.nn.softmax(s, -1)
        o = jnp.einsum("khs,skd->khd", p, vals).reshape(H, D)
        np.testing.assert_allclose(out[g], o, rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("G,K,seed", [(2, 1, 0), (6, 3, 1), (4, 2, 42)])
def test_merge_exactness_cases(G, K, seed):
    """Deterministic fallback cases (always run, hypothesis or not)."""
    _check_merge_exactness(G, K, seed)


if HAVE_HYPOTHESIS:
    @needs_hypothesis
    @given(st.integers(2, 6), st.integers(1, 3), st.integers(0, 2**31 - 1))
    @settings(max_examples=15, deadline=None)
    def test_property_merge_exactness(G, K, seed):
        _check_merge_exactness(G, K, seed)


# ---------------------------------------------------------------------------
# end-to-end: model + store
# ---------------------------------------------------------------------------

def test_moska_decode_equals_monolithic_context():
    cfg = dataclasses.replace(get_config("tinyllama-1.1b").reduced(),
                              dtype="float32")
    params = dense.init_params(cfg, jax.random.PRNGKey(0))
    B, S, CL = 2, 17, 128
    toks = jax.random.randint(jax.random.fold_in(KEY, 1), (B, S), 0,
                              cfg.vocab_size)
    ctoks = jax.random.randint(jax.random.fold_in(KEY, 2), (1, CL), 0,
                               cfg.vocab_size)
    ccache = init_kv_cache(cfg.num_layers, 1, CL, cfg.num_kv_heads,
                           cfg.head_dim, jnp.float32)
    _, ccache = dense.prefill(cfg, params, ctoks, ccache)
    store = build_store(ccache.k[:, 0], ccache.v[:, 0],
                        cfg.moska.chunk_size)
    cfgf = dataclasses.replace(cfg, moska=dataclasses.replace(
        cfg.moska, top_k_chunks=store.num_chunks))
    cache = init_kv_cache(cfg.num_layers, B, S + 4, cfg.num_kv_heads,
                          cfg.head_dim, jnp.float32)
    _, cache = dense.prefill(cfgf, params, toks[:, :S - 1], cache,
                             store=store, start_pos=CL)
    ld, _ = dense.decode_step(cfgf, params, toks[:, S - 1], cache,
                              store=store)
    full = jnp.concatenate([jnp.tile(ctoks, (B, 1)), toks], 1)
    cache2 = init_kv_cache(cfg.num_layers, B, CL + S + 4, cfg.num_kv_heads,
                           cfg.head_dim, jnp.float32)
    lf, _ = dense.prefill(cfg, params, full, cache2)
    np.testing.assert_allclose(ld, lf, rtol=2e-4, atol=2e-4)


def test_sparse_routing_approximates_dense():
    """top-1 of 2 chunks: finite, and closer to exact than random logits."""
    cfg = dataclasses.replace(get_config("tinyllama-1.1b").reduced(),
                              dtype="float32")
    params = dense.init_params(cfg, jax.random.PRNGKey(0))
    B, S, CL = 2, 9, 128
    toks = jax.random.randint(jax.random.fold_in(KEY, 3), (B, S), 0,
                              cfg.vocab_size)
    ctoks = jax.random.randint(jax.random.fold_in(KEY, 4), (1, CL), 0,
                               cfg.vocab_size)
    ccache = init_kv_cache(cfg.num_layers, 1, CL, cfg.num_kv_heads,
                           cfg.head_dim, jnp.float32)
    _, ccache = dense.prefill(cfg, params, ctoks, ccache)
    store = build_store(ccache.k[:, 0], ccache.v[:, 0],
                        cfg.moska.chunk_size)
    sparse = dataclasses.replace(cfg, moska=dataclasses.replace(
        cfg.moska, top_k_chunks=1))
    cache = init_kv_cache(cfg.num_layers, B, S + 4, cfg.num_kv_heads,
                          cfg.head_dim, jnp.float32)
    _, cache = dense.prefill(sparse, params, toks[:, :S - 1], cache,
                             store=store, start_pos=CL)
    ld, _ = dense.decode_step(sparse, params, toks[:, S - 1], cache,
                              store=store)
    assert np.isfinite(np.asarray(ld)).all()


def test_pallas_kernel_path_matches_jnp_path():
    """decode with kernel='pallas' must equal the jnp shared path."""
    cfg = dataclasses.replace(get_config("tinyllama-1.1b").reduced(),
                              dtype="float32")
    params = dense.init_params(cfg, jax.random.PRNGKey(0))
    B, CL = 2, 128
    ctoks = jax.random.randint(jax.random.fold_in(KEY, 5), (1, CL), 0,
                               cfg.vocab_size)
    ccache = init_kv_cache(cfg.num_layers, 1, CL, cfg.num_kv_heads,
                           cfg.head_dim, jnp.float32)
    _, ccache = dense.prefill(cfg, params, ctoks, ccache)
    store = build_store(ccache.k[:, 0], ccache.v[:, 0],
                        cfg.moska.chunk_size)
    cache = init_kv_cache(cfg.num_layers, B, 8, cfg.num_kv_heads,
                          cfg.head_dim, jnp.float32)
    toks = jax.random.randint(jax.random.fold_in(KEY, 6), (B, 4), 0,
                              cfg.vocab_size)
    _, cache = dense.prefill(cfg, params, toks, cache, store=store,
                             start_pos=CL)
    l1, _ = dense.decode_step(cfg, params, toks[:, -1], cache, store=store)
    l2, _ = dense.decode_step(cfg, params, toks[:, -1], cache, store=store,
                              kernel="pallas")
    np.testing.assert_allclose(l1, l2, rtol=2e-4, atol=2e-4)
