"""While-aware HLO coster: trip-count multiplication, dot flops, collective
byte extraction — validated on real compiled modules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import HloModule, analyze_hlo, _shape_bytes
from repro.launch.roofline import collective_bytes


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_scan_trip_count_multiplied():
    def f(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    flops = {}
    for L in (2, 16):
        ws = jax.ShapeDtypeStruct((L, 128, 128), jnp.float32)
        cost = analyze_hlo(_compile(f, x, ws).as_text())
        flops[L] = cost.flops
        assert cost.flops == pytest.approx(2 * 128**3 * L, rel=0.01), L
    assert flops[16] == pytest.approx(8 * flops[2], rel=0.01)


def test_nested_scan_trip_counts():
    def f(x, ws):
        def outer(c, w):
            def inner(c2, _):
                return jnp.tanh(c2 @ w), None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        y, _ = jax.lax.scan(outer, x, ws)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((4, 64, 64), jnp.float32)
    cost = analyze_hlo(_compile(f, x, ws).as_text())
    assert cost.flops == pytest.approx(2 * 64**3 * 4 * 3, rel=0.01)


def test_dot_flops_rectangular():
    def f(a, b):
        return a @ b
    a = jax.ShapeDtypeStruct((64, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 32), jnp.float32)
    cost = analyze_hlo(_compile(f, a, b).as_text())
    assert cost.flops == pytest.approx(2 * 64 * 256 * 32, rel=0.01)


def test_shape_bytes_parses_tuples_and_dtypes():
    assert _shape_bytes("f32[4,8]") == 128
    assert _shape_bytes("bf16[10]") == 20
    assert _shape_bytes("(s32[], f32[256,256]{1,0})") == 4 + 256 * 256 * 4
    assert _shape_bytes("pred[]") == 1


def test_collective_bytes_regex():
    hlo = """
  %ag = f32[64,128]{1,0} all-gather(%x), dimensions={0}
  %ar.1 = bf16[32]{0} all-reduce(%y), to_apply=%sum
  %done = f32[8]{0} all-gather-done(%start)
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 64 * 128 * 4
    assert out["all-reduce"] == 32 * 2


def test_module_entry_detection():
    def f(x):
        return x * 2 + 1
    x = jax.ShapeDtypeStruct((32,), jnp.float32)
    m = HloModule(_compile(f, x).as_text())
    assert m.entry is not None
    assert m.entry in m.computations
