"""Differential harness for the shared-KV GEMM path.

Pins the Pallas kernel (interpret mode on CPU) against the two reference
implementations across ragged shapes:

  * ``shared_attention_batched(kernel='pallas')`` vs
    ``shared_attention_batched(kernel=None)`` (jnp math) vs
    ``shared_attention_gather_ref`` (per-request gather oracle)
  * raw ``kernels.shared_chunk_attn`` vs the jnp per-chunk reference with a
    kv-tile size that does NOT divide the chunk length (ragged tail tile)

Cases: chunk length not a multiple of ``block_c``, capacity overflow
(dropped queries), empty chunks (no queries routed), and single-query
groups. Output and LSE must agree to fp32 tolerance.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import router as router_lib
from repro.core import shared_attention as sa
from repro.core.router import Routing
from repro.kernels import ops as kops

KEY = jax.random.PRNGKey(0)
TOL = dict(rtol=3e-5, atol=3e-5)


def _kv(E, C, KH, D, key=KEY):
    k = jax.random.normal(jax.random.fold_in(key, 1), (E, C, KH, D),
                          jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (E, C, KH, D),
                          jnp.float32)
    return k, v


def _routing(chunk_ids, E):
    ids = jnp.asarray(chunk_ids, jnp.int32)
    G, K = ids.shape
    return Routing(ids, jnp.zeros((G, K), jnp.float32),
                   jnp.zeros((G, E), jnp.float32))


def _rand_routing(G, K, E, seed=0):
    # distinct chunks per group (routing semantics: top-k without repeats)
    keys = jax.random.split(jax.random.PRNGKey(seed), G)
    ids = jnp.stack([jax.random.permutation(k, E)[:K] for k in keys])
    return _routing(ids, E)


def _assert_partials_close(a, b, **tol):
    np.testing.assert_allclose(a.out, b.out, **(tol or TOL))
    np.testing.assert_allclose(a.lse, b.lse, **(tol or TOL))


# ---------------------------------------------------------------------------
# full path: pallas == jnp == gather oracle (no drops)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("G,Q,K,E,C,H,KH,D", [
    (6, 1, 3, 8, 16, 8, 2, 32),     # decode-shaped
    (4, 8, 2, 8, 16, 8, 2, 32),     # prefill blocks
    (1, 1, 1, 4, 8, 4, 4, 16),      # single-query group, MHA
    (1, 4, 8, 8, 8, 4, 1, 16),      # one group routed everywhere, MQA
    (5, 1, 2, 3, 24, 8, 2, 32),     # C=24: not 8/128-aligned
])
def test_pallas_vs_jnp_vs_gather(G, Q, K, E, C, H, KH, D):
    k, v = _kv(E, C, KH, D)
    r = _rand_routing(G, K, E, seed=G * 100 + K)
    q = jax.random.normal(jax.random.fold_in(KEY, 3), (G, Q, H, D),
                          jnp.float32)
    cap = G * K   # no capacity drops => all three must agree exactly
    ref = sa.shared_attention_gather_ref(q, k, v, r)
    jnp_p = sa.shared_attention_batched(q, k, v, r, capacity=cap)
    pal_p = sa.shared_attention_batched(q, k, v, r, capacity=cap,
                                        kernel="pallas")
    _assert_partials_close(jnp_p, ref)
    _assert_partials_close(pal_p, ref)
    _assert_partials_close(pal_p, jnp_p)


def test_ragged_chunk_vs_block_c_through_full_path():
    """block_c does not divide C: the kernel's tail-tile masking must keep
    the full path equal to the gather oracle."""
    G, Q, K, E, C, H, KH, D = 4, 1, 2, 4, 24, 8, 2, 32
    k, v = _kv(E, C, KH, D)
    r = _rand_routing(G, K, E, seed=7)
    q = jax.random.normal(jax.random.fold_in(KEY, 4), (G, Q, H, D),
                          jnp.float32)
    ref = sa.shared_attention_gather_ref(q, k, v, r)
    for block_c in (16, 10, 24, 7):
        pal = sa.shared_attention_batched(q, k, v, r, capacity=G * K,
                                          kernel="pallas", block_c=block_c)
        _assert_partials_close(pal, ref)


# ---------------------------------------------------------------------------
# raw kernel vs jnp per-chunk reference (direct dispatch control)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("E,cap,H,KH,D,C,block_c", [
    (4, 8, 8, 2, 32, 24, 16),       # ragged tail tile (24 = 16 + 8)
    (3, 8, 4, 4, 16, 17, 8),        # prime C, multiple ragged tiles
    (2, 16, 8, 1, 32, 32, 32),      # exact tiling, MQA
    (5, 8, 8, 2, 16, 5, 8),         # C < block_c (single clamped tile)
])
def test_kernel_vs_reference_ragged(E, cap, H, KH, D, C, block_c):
    key = jax.random.fold_in(KEY, E * 1000 + C)
    k, v = _kv(E, C, KH, D, key)
    qd = jax.random.normal(jax.random.fold_in(key, 3), (E, cap, H, D),
                           jnp.float32)
    # ragged validity incl. one fully-empty chunk (chunk 0: no queries)
    qmask = jax.random.bernoulli(jax.random.fold_in(key, 4), 0.6, (E, cap))
    qmask = qmask.at[0].set(False)
    out_k, lse_k = kops.shared_chunk_attention(qd, k, v, qmask,
                                               block_c=block_c)
    out_r, lse_r = sa._chunk_batched_attention(qd[:, :, None], k, v, qmask)
    # masked slots: kernel zeroes the output, reference leaves it dangling
    # (both mark lse = -inf) — compare outputs on valid slots only
    valid = np.asarray(qmask)[:, :, None, None]
    np.testing.assert_allclose(np.where(valid, np.asarray(out_k), 0.0),
                               np.where(valid, np.asarray(out_r[:, :, 0]),
                                        0.0), **TOL)
    np.testing.assert_allclose(lse_k, lse_r[:, :, 0], **TOL)
    assert np.isfinite(np.asarray(out_k)).all()
    assert np.all(np.asarray(out_k)[~np.asarray(qmask)] == 0.0)
    # empty chunk: masked slots carry the -inf sentinel and zero output
    assert np.all(np.asarray(lse_k[0]) <= sa.NEG_INF / 2)
    assert np.all(np.asarray(out_k[0]) == 0.0)


# ---------------------------------------------------------------------------
# capacity overflow: drops must be identical across implementations
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("capacity", [1, 2, 8])
def test_capacity_overflow_pallas_equals_jnp(capacity):
    G, Q, K, E, C, H, KH, D = 8, 1, 2, 4, 16, 8, 2, 32
    k, v = _kv(E, C, KH, D)
    r = _rand_routing(G, K, E, seed=3)
    q = jax.random.normal(jax.random.fold_in(KEY, 5), (G, Q, H, D),
                          jnp.float32)
    jnp_p = sa.shared_attention_batched(q, k, v, r, capacity=capacity)
    pal_p = sa.shared_attention_batched(q, k, v, r, capacity=capacity,
                                        kernel="pallas")
    _assert_partials_close(pal_p, jnp_p)
    assert np.isfinite(np.asarray(pal_p.out)).all()
    # with G*K = 16 routes into E*capacity slots, overflow must drop:
    # groups whose every route dropped carry the -inf LSE sentinel
    if capacity * E < G * K:
        flat, pos, keep = router_lib.dispatch_plan(r.chunk_ids, E, capacity)
        keep = np.asarray(keep).reshape(G, K)
        lse = np.asarray(pal_p.lse)
        for g in range(G):
            if not keep[g].any():
                assert np.all(lse[g] <= sa.NEG_INF / 2)
            else:
                assert np.isfinite(lse[g]).all()


def test_empty_chunks_full_path():
    """All groups route to a single chunk; the other chunks run empty
    through the kernel and must not perturb the result."""
    G, Q, E, C, H, KH, D = 5, 1, 6, 8, 8, 2, 16
    k, v = _kv(E, C, KH, D)
    r = _routing(np.zeros((G, 1), np.int32), E)
    q = jax.random.normal(jax.random.fold_in(KEY, 6), (G, Q, H, D),
                          jnp.float32)
    ref = sa.shared_attention_gather_ref(q, k, v, r)
    for kern in (None, "pallas"):
        got = sa.shared_attention_batched(q, k, v, r, capacity=G,
                                          kernel=kern)
        _assert_partials_close(got, ref)
