"""Per-architecture smoke tests (assignment requirement (f)).

Every assigned architecture instantiates its REDUCED variant (<=2 layers,
d_model<=512, <=4 experts) and runs one forward/train step + a
prefill/decode round on CPU, asserting output shapes and no NaNs.
The FULL configs are exercised only via the dry-run (no allocation).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.configs.base import AUDIO, SSM, VLM
from repro.data.pipeline import make_train_batches
from repro.models.model import build_model

KEY = jax.random.PRNGKey(0)


def _smoke_batch(cfg, B=2, S=32):
    return next(make_train_batches(cfg, B, S, num_batches=1))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_train_step(arch):
    cfg = get_config(arch).reduced()
    assert cfg.num_layers <= 3 and cfg.d_model <= 512
    if cfg.moe.enabled:
        assert cfg.moe.num_experts <= 4
    model = build_model(cfg)
    params = model.init(KEY)
    batch = {k: jnp.asarray(v) for k, v in _smoke_batch(cfg).items()}
    loss, metrics = model.train_loss(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    # one grad step must be finite too
    g = jax.grad(lambda p: model.train_loss(p, batch)[0])(params)
    flat = jax.tree.leaves(g)
    assert all(bool(jnp.all(jnp.isfinite(x.astype(jnp.float32))))
               for x in flat), f"{arch}: non-finite grads"


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_prefill_decode(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(KEY)
    B, S = 2, 16
    toks = jax.random.randint(jax.random.fold_in(KEY, 1), (B, S), 0,
                              cfg.vocab_size)
    extra = 0
    kw = {}
    if cfg.family in (VLM, AUDIO):
        F = cfg.encoder.frontend_seq or 16
        kw["frontend_embeds"] = jax.random.normal(
            jax.random.fold_in(KEY, 2), (B, F, cfg.encoder.frontend_dim or
                                         cfg.d_model), jnp.float32)
        if cfg.family == VLM:
            extra = F  # patch embeddings are prepended to the sequence
    cache = model.init_cache(B, S + extra + 8, jnp.float32)
    logits, cache = model.prefill(params, toks, cache, **kw)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all(), f"{arch}: prefill NaN"
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(3):
        logits, cache = model.decode_step(params, nxt, cache)
        assert logits.shape == (B, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits)).all(), f"{arch}: decode NaN"
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "mamba2-130m",
                                  "recurrentgemma-9b", "whisper-tiny"])
def test_prefill_decode_consistency(arch):
    """decode(prefill(S-1), token S-1) == prefill(S) — per family."""
    cfg = dataclasses.replace(get_config(arch).reduced(), dtype="float32")
    model = build_model(cfg)
    params = model.init(KEY)
    B, S = 2, 12
    toks = jax.random.randint(jax.random.fold_in(KEY, 3), (B, S), 0,
                              cfg.vocab_size)
    kw = {}
    if cfg.family in (VLM, AUDIO):
        F = cfg.encoder.frontend_seq or 16
        kw["frontend_embeds"] = jax.random.normal(
            jax.random.fold_in(KEY, 4), (B, F, cfg.encoder.frontend_dim or
                                         cfg.d_model), jnp.float32)
    c1 = model.init_cache(B, S + 4, jnp.float32)
    _, c1 = model.prefill(params, toks[:, :S - 1], c1, **kw)
    ld, _ = model.decode_step(params, toks[:, S - 1], c1)
    c2 = model.init_cache(B, S + 4, jnp.float32)
    lf, _ = model.prefill(params, toks, c2, **kw)
    np.testing.assert_allclose(np.asarray(ld), np.asarray(lf),
                               rtol=2e-3, atol=2e-3)
