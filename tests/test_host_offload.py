"""Host-tier offload differentials and determinism pins.

The host memory tier's contract: enabling it changes *where* evicted
prefix pages live, never *what* the engine generates. A cold-prefix
workload (a fixed device pool too small to keep parked prefixes
resident, run twice over the same prompt stream) must produce
bit-identical generations across:

  * the slotted layout (no paging at all),
  * paged without a host tier (cold hits rebuild from tokens),
  * paged with a host tier (cold hits swap pages back in),
  * paged with a one-block host tier (the host tier itself LRU-evicts,
    so hits fall through to the rebuild path),
  * paged with prefix sharing off entirely.

On top of bit-identity, the swap-in config must serve strictly fewer
prefill tokens than the rebuild config — that is the whole point of the
tier, and the CI bench gate (``offload_vs_rebuild``) enforces the same
inequality at a different workload.

Also pinned here: LRU eviction order for both tiers (insertion-then-
touch, regression-pinned exactly), the scheduler's offload-vs-defer
decision at the exact block-budget boundary, and the multi-corpus
prefix keying (same corpus *content* under different store ids shares
one prefix namespace; different content does not).
"""
import os

import numpy as np
import pytest

from repro import obs
from repro.configs import get_config
from repro.core.scheduler import Scheduler, SchedulerConfig
from repro.data.pipeline import CorpusSpec, synthesize_corpus
from repro.kvcache.paged import HostBlockPool
from repro.models.model import build_model
from repro.serving.engine import EngineConfig, ServingEngine

import jax

_STATE = {}

# two passes over the same prompts with a 3-usable-block pool: every
# parked prefix is evicted between waves, so each pass-2 prompt is a
# cold hit (swap-in, host-evicted miss, or rebuild, per config)
COLD_PROMPTS = [[10 + i] * 8 for i in range(4)]

# CI runs this suite once per reference layout: "slotted" anchors the
# host-tier configs against the slab oracle, "paged" against the
# paged-without-offload engine (an ample, never-evicting pool)
REF_LAYOUT = os.environ.get("HOST_OFFLOAD_REF_LAYOUT", "slotted")


def _setup():
    if not _STATE:
        cfg = get_config("tinyllama-1.1b").reduced()
        model = build_model(cfg)
        _STATE["cfg"] = cfg
        _STATE["params"] = model.init(jax.random.PRNGKey(0))
    return _STATE["cfg"], _STATE["params"]


def _run(layout, prompts=COLD_PROMPTS, passes=2, reverse_odd=False, **kw):
    """Run ``passes`` waves of ``prompts`` on a fresh engine; returns
    ((pass, prompt)-keyed generations, metrics snapshot, engine). With
    ``reverse_odd`` odd passes submit in reverse order — arrival order
    is a scheduling detail, so generations must not depend on it."""
    cfg, params = _setup()
    obs.reset_registry()
    eng = ServingEngine(cfg, params,
                        EngineConfig(max_slots=2, max_seq=64,
                                     kv_layout=layout, **kw))
    gens = {}
    for i in range(passes):
        wave = prompts[::-1] if (reverse_odd and i % 2) else prompts
        for p in wave:
            eng.submit(p, max_new_tokens=4)
        for r in eng.run():
            gens[(i, tuple(r.prompt))] = tuple(r.generated)
        eng.scheduler.finished.clear()
    return gens, obs.get_registry().snapshot(), eng


def _ref_run(**kw):
    """Reference generations under the CI-selected oracle layout."""
    if REF_LAYOUT == "paged":
        return _run("paged", block_size=16, num_blocks=64, **kw)
    return _run("slotted", **kw)


def _counter(snap, name):
    return int(snap.get(name, {}).get("value", 0))


def test_offload_differential_bit_identical():
    paged = dict(block_size=16, num_blocks=4)
    ref, _, _ = _ref_run()
    rebuild, rsnap, _ = _run("paged", host_pool_blocks=0, **paged)
    swap, ssnap, seng = _run("paged", host_pool_blocks=16, **paged)
    noshare, _, _ = _run("paged", share_prefix_blocks=False, **paged)
    # one-block host tier + reversed second pass: arrival order fights
    # the tier's FIFO eviction order, so the tier itself churns
    ref_rev, _, _ = _ref_run(reverse_odd=True)
    churn, csnap, _ = _run("paged", host_pool_blocks=1, reverse_odd=True,
                           **paged)

    # one contract for every tier configuration: identical generations
    assert rebuild == ref
    assert swap == ref
    assert noshare == ref
    assert churn == ref_rev

    # swap-in path: pass 2 swaps pages back instead of re-prefilling
    assert _counter(ssnap, "kvcache/swap_in_hits") >= 1
    assert _counter(ssnap, "kvcache/offload_bytes") > 0
    assert _counter(ssnap, "kvcache/swap_in_bytes") > 0
    assert _counter(ssnap, "engine/prefill_tokens") < \
        _counter(rsnap, "engine/prefill_tokens")
    # drained clean: every live block is a parked prefix page (held only
    # by the cache), no slot leaked a reference
    parked = {b for e in seng._prefix_cache.values() for b in e["blocks"]}
    assert seng._block_pool.in_use == len(parked)
    assert all(seng._block_pool.refcount(b) == 1 for b in parked)

    # one-block host tier: the tier itself churns, hits fall through to
    # the deterministic rebuild path (host_pool_misses)
    assert _counter(csnap, "kvcache/host_pool_evictions") >= 1
    assert _counter(csnap, "kvcache/host_pool_misses") >= 1
    assert _counter(csnap, "kvcache/swap_in_hits") < len(COLD_PROMPTS)


def test_host_tier_invisible_under_cow_divergence():
    # ample pool: prefix hits stay device-resident and decode appends
    # into shared tail pages (copy-on-write); the enabled-but-idle host
    # tier must not perturb that path either
    prompts = COLD_PROMPTS + [COLD_PROMPTS[0]]   # duplicate => CoW
    ref, _, _ = _ref_run(prompts=prompts)
    got, snap, _ = _run("paged", prompts=prompts, block_size=16,
                        num_blocks=64, host_pool_blocks=16)
    assert got == ref
    assert _counter(snap, "kvcache/cow_copies") >= 1
    assert _counter(snap, "kvcache/prefix_hits") >= 1


def test_multi_corpus_prefix_keying_by_content():
    cfg, params = _setup()
    toks = synthesize_corpus(CorpusSpec("shared", 128, cfg.vocab_size))
    other = synthesize_corpus(CorpusSpec("other", 128, cfg.vocab_size,
                                         seed=7))
    prompt = [5] * 8
    obs.reset_registry()
    eng = ServingEngine(cfg, params,
                        EngineConfig(max_slots=2, max_seq=64,
                                     kv_layout="paged", block_size=16))
    eng.register_corpus("c0", toks)
    eng.register_corpus("c1", toks)        # same content, different id
    eng.register_corpus("c2", other)       # different content
    gens = {}
    for cid in ("c0", "c1", "c2"):
        eng.submit(prompt, max_new_tokens=4, corpus_id=cid)
        gens[cid] = tuple(eng.run()[0].generated)
        eng.scheduler.finished.clear()
    snap = obs.get_registry().snapshot()
    # identical content => same fingerprint => the c1 request hits the
    # prefix entry the c0 request parked, across store ids
    assert _counter(snap, "kvcache/prefix_hits") == 1
    assert gens["c0"] == gens["c1"]
    # different content must NOT share the namespace (its unique KV is
    # conditioned on a different shared context)
    assert len(eng._prefix_cache) == 2     # (shared-fp, p) and (other-fp, p)


def test_host_pool_lru_order_pinned():
    def pages(nb):
        a = np.zeros((1, nb, 1, 1, 1), np.float32)
        return a, a

    hp = HostBlockPool(3)
    for key in ("a", "b", "c"):
        assert hp.offload(key, *pages(1), first=0) == []
    assert hp.keys() == ["a", "b", "c"]    # insertion order
    assert hp.touch("a")
    assert hp.keys() == ["b", "c", "a"]    # touch refreshes to MRU
    # a two-block insert must evict exactly the two LRU entries, oldest
    # first — regression-pinned order, not just membership
    assert hp.offload("d", *pages(2), first=0) == ["b", "c"]
    assert hp.keys() == ["a", "d"]
    assert hp.used_blocks == 3 and hp.evictions == 2
    # refresh of an existing key re-inserts at the MRU end
    assert hp.offload("a", *pages(1), first=0) == []
    assert hp.keys() == ["d", "a"]
    hp.check_invariants()


def test_device_prefix_cache_lru_order_pinned():
    cfg, params = _setup()
    eng = ServingEngine(cfg, params,
                        EngineConfig(max_slots=2, max_seq=64,
                                     kv_layout="paged", block_size=16,
                                     num_blocks=8))
    bp = eng._block_pool
    for key in ("a", "b", "c"):
        eng._prefix_cache[key] = {"blocks": bp.alloc(1), "first": 0}
    eng._prefix_cache.move_to_end("a")     # hit refreshes to MRU
    released, evicted = eng._evict_prefix_entries(None, 2)
    assert released == 2
    assert evicted == ["b", "c"]           # insertion-then-touch order
    assert list(eng._prefix_cache) == ["a"]
    bp.check_invariants()


def test_scheduler_offload_vs_defer_at_budget_boundary():
    # one request costs exactly one block (16 tokens * 1 B/token); the
    # budget holds exactly one block, but cold prefix pages already fill
    # it — admission must offload them, not defer
    def mk(budget, cold_start, can_free):
        obs.reset_registry()
        s = Scheduler(SchedulerConfig(
            max_slots=2, mem_budget_bytes=budget,
            unique_bytes_per_token=1.0, max_seq=64,
            kv_layout="paged", block_size=16))
        cold = {"bytes": float(cold_start)}
        asked = []

        def offload(need):
            asked.append(need)
            if not can_free:
                return 0.0
            freed = min(cold["bytes"], need)
            cold["bytes"] -= freed
            return freed

        s.set_page_offloader(lambda: cold["bytes"], offload)
        s.submit([1] * 12, 4)              # 16 tokens => 16 bytes
        return s, s.schedule(), asked

    # boundary fit: cold pages + request == budget exactly => no offload
    s, admitted, asked = mk(budget=32.0, cold_start=16.0, can_free=True)
    assert len(admitted) == 1 and asked == []

    # one byte short: the shortfall is offloaded and the work admitted
    s, admitted, asked = mk(budget=31.0, cold_start=16.0, can_free=True)
    snap = obs.get_registry().snapshot()
    assert len(admitted) == 1
    assert asked == [1.0]                  # asks for the exact shortfall
    assert _counter(snap, "scheduler/offload_admissions") == 1
    assert _counter(snap, "scheduler/admission_deferred_mem") == 0

    # nothing reclaimable: same pressure now defers instead
    s, admitted, asked = mk(budget=31.0, cold_start=16.0, can_free=False)
    snap = obs.get_registry().snapshot()
    assert admitted == [] and asked == [1.0]
    assert _counter(snap, "scheduler/offload_admissions") == 0
    assert _counter(snap, "scheduler/admission_deferred_mem") == 1
    assert len(s.queue) == 1               # still queued, not dropped


def test_slotted_layout_rejects_host_pool():
    cfg, params = _setup()
    with pytest.raises(ValueError, match="paged"):
        ServingEngine(cfg, params,
                      EngineConfig(max_slots=2, max_seq=64,
                                   host_pool_blocks=4))
