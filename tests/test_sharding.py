"""Sharding-rule resolution: divisibility guard, axis-conflict avoidance,
variant application, param pspec mapping."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.sharding import specs as sp


MESH_AXES = ("data", "model")
SIZES = {"data": 16, "model": 16}


def _resolve(rules, names, shape):
    return sp._resolve(rules, names, MESH_AXES, shape, SIZES)


def test_divisibility_guard_drops_nondividing_axis():
    rules = {"kv_heads": "model", "batch": "data"}
    # 8 kv heads cannot shard over model=16 -> replicated
    assert _resolve(rules, ("batch", "kv_heads"), (128, 8)) == P("data", None)
    # 16 kv heads can
    assert _resolve(rules, ("batch", "kv_heads"), (128, 16)) == \
        P("data", "model")


def test_axis_used_once():
    rules = {"a": "model", "b": "model"}
    # the second request for "model" must be dropped, not duplicated
    assert _resolve(rules, ("a", "b"), (32, 32)) == P("model", None)


def test_tuple_axes_partial_divisibility():
    rules = {"batch": ("pod", "data")}
    # no 'pod' axis in this mesh: falls back to data alone
    assert _resolve(rules, ("batch",), (32,)) == P("data")


def test_apply_variant_overrides():
    rules = sp.apply_variant(sp.SERVE_RULES, "weights_resident")
    assert rules["p_dm"] is None
    assert sp.SERVE_RULES["p_dm"] == "data"  # original untouched
    both = sp.apply_variant(sp.TRAIN_RULES, "seqpar")
    assert both["seq_res"] == "model"


def test_param_pspecs_name_mapping():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    params = {
        "layers": {
            "attn": {"wq": jnp.zeros((4, 64, 128))},   # stacked (L, d, h)
            "mlp": {"w_down": jnp.zeros((4, 128, 64))},
        },
        "embed": {"embed": jnp.zeros((1000, 64))},
        "final_norm": {"scale": jnp.zeros((64,))},
    }
    specs = sp.param_pspecs(params, sp.TRAIN_RULES, mesh)
    # leading scan dim maps to None; named dims resolved (mesh size 1 so
    # everything divisible)
    assert specs["layers"]["attn"]["wq"] == P(None, "data", "model")
    assert specs["layers"]["mlp"]["w_down"] == P(None, "model", "data")
    assert specs["embed"]["embed"] == P("model", None)
    assert specs["final_norm"]["scale"] == P(None)


def test_lsc_identity_without_rules():
    sp.set_rules(None)
    x = jnp.ones((4, 4))
    assert sp.lsc(x, "batch", "d_model") is x


def test_lsc_rank_alignment():
    """Names align from the right when rank differs (decode drops seq)."""
    mesh = jax.make_mesh((1,), ("data",))
    with mesh:
        sp.set_rules({"d_ff": "data"})
        try:
            x = jnp.ones((2, 8))
            y = sp.lsc(x, None, None, "d_ff")  # 3 names, rank 2
            assert y.shape == x.shape
        finally:
            sp.set_rules(None)
