"""Unit tests for the observability layer (repro.obs) and the LSE-merge
kernel edge cases it keeps honest.

Covers: histogram bucketing (edges, overflow, quantiles), counter/gauge
semantics, span nesting (parent/depth), exporter round-trip (JSON and line
protocol), jit-safe recording through jax.debug.callback, and
kernels/lse_merge.py on all-(-inf) LSE rows and merge associativity.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.kernels.lse_merge import NEG_INF, lse_merge


@pytest.fixture()
def fresh_registry():
    """Isolated registry + restored jit-metrics flag per test."""
    reg = obs.MetricsRegistry()
    prev_reg = obs.set_registry(reg)
    prev_flag = obs.metrics.JIT_METRICS
    try:
        yield reg
    finally:
        obs.set_registry(prev_reg)
        obs.enable_jit_metrics(prev_flag)


# ---------------------------------------------------------------------------
# metrics primitives
# ---------------------------------------------------------------------------

def test_counter_and_gauge(fresh_registry):
    reg = fresh_registry
    reg.inc("c")
    reg.inc("c", 2.5)
    assert reg.counter("c").value == 3.5
    with pytest.raises(ValueError):
        reg.counter("c").inc(-1)
    reg.set_gauge("g", 5)
    reg.set_gauge("g", -2)
    g = reg.gauge("g")
    assert (g.value, g.min, g.max, g.updates) == (-2.0, -2.0, 5.0, 2)
    with pytest.raises(TypeError):
        reg.gauge("c")          # kind mismatch


def test_histogram_bucketing(fresh_registry):
    h = fresh_registry.histogram("h", edges=(1.0, 2.0, 5.0))
    for v in (0.5, 1.0, 1.5, 2.0, 4.9, 5.0, 7.0, 100.0):
        h.observe(v)
    # v <= edge lands in that bucket; > last edge overflows
    assert h.counts == [2, 2, 2, 2]
    assert h.count == 8
    assert h.sum == pytest.approx(121.9)
    assert (h.min, h.max) == (0.5, 100.0)
    assert h.mean == pytest.approx(121.9 / 8)
    assert h.quantile(0.25) == 1.0
    assert h.quantile(1.0) == 100.0     # overflow bucket reports max
    with pytest.raises(ValueError):
        fresh_registry.histogram("bad", edges=(2.0, 1.0))


def test_histogram_snapshot_shape(fresh_registry):
    h = fresh_registry.histogram("h", edges=obs.FRACTION_EDGES)
    h.observe(0.35)
    snap = h.snapshot()
    assert len(snap["counts"]) == len(snap["edges"]) + 1
    assert sum(snap["counts"]) == snap["count"] == 1


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------

def test_span_nesting(fresh_registry):
    reg = fresh_registry
    with obs.span("outer", registry=reg):
        assert obs.current_span().name == "outer"
        with obs.span("inner", registry=reg, wave=3):
            assert obs.current_span().depth == 1
    assert obs.current_span() is None
    by_name = {s.name: s for s in reg.spans}
    assert by_name["inner"].parent == "outer"
    assert by_name["inner"].depth == 1
    assert by_name["outer"].parent is None
    assert by_name["inner"].attrs == {"wave": 3}
    assert by_name["outer"].duration_s >= by_name["inner"].duration_s >= 0
    # spans auto-feed latency histograms
    assert reg.histogram("span/outer/duration_s").count == 1


def test_span_records_on_exception(fresh_registry):
    reg = fresh_registry
    with pytest.raises(RuntimeError):
        with obs.span("boom", registry=reg):
            raise RuntimeError("x")
    assert [s.name for s in reg.spans] == ["boom"]
    assert obs.current_span() is None       # stack unwound


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------

def test_exporter_json_round_trip(fresh_registry, tmp_path):
    reg = fresh_registry
    reg.inc("scheduler/admitted", 4)
    reg.set_gauge("scheduler/slot_occupancy", 0.75)
    reg.observe("engine/decode_step_latency_s", 0.003)
    with obs.span("engine.run", registry=reg):
        pass
    path = str(tmp_path / "m.json")
    obs.dump(path, reg)
    back = obs.load(path)
    assert back.snapshot() == reg.snapshot()
    assert [s.name for s in back.spans] == [s.name for s in reg.spans]
    # and via the in-memory dict path too
    assert obs.from_dict(obs.to_dict(reg)).snapshot() == reg.snapshot()


def test_exporter_rejects_unknown_schema(fresh_registry):
    with pytest.raises(ValueError):
        obs.from_dict({"schema_version": 999, "metrics": {}})


def test_line_protocol(fresh_registry, tmp_path):
    reg = fresh_registry
    reg.inc("tokens", 12)
    reg.observe("lat", 0.2, edges=(0.1, 1.0))
    lines = obs.to_lines(reg)
    assert "tokens value=12.0" in lines
    assert "lat,le=1.0 count=1" in lines
    assert any(line.startswith("lat count=1 sum=0.2") for line in lines)
    path = str(tmp_path / "m.lp")
    obs.dump(path, reg)
    assert open(path).read().strip() == "\n".join(lines)


# ---------------------------------------------------------------------------
# jit-safe recording
# ---------------------------------------------------------------------------

def test_jit_metrics_record_per_execution(fresh_registry):
    reg = fresh_registry
    obs.enable_jit_metrics(True)

    @jax.jit
    def f(x):
        obs.jit_inc("jit/calls", 1)
        obs.jit_observe("jit/mean", jnp.mean(x), edges=obs.FRACTION_EDGES)
        return x + 1

    for _ in range(3):
        f(jnp.full((4,), 0.5)).block_until_ready()
    # trace-time-only recording would show 1; per-execution shows 3
    assert reg.counter("jit/calls").value == 3
    assert reg.histogram("jit/mean", obs.FRACTION_EDGES).count == 3


def test_jit_metrics_disabled_is_noop(fresh_registry):
    reg = fresh_registry
    obs.enable_jit_metrics(False)

    @jax.jit
    def f(x):
        obs.jit_inc("jit/calls", 1)
        return x + 1

    f(jnp.zeros((2,))).block_until_ready()
    assert reg.get("jit/calls") is None


def test_dispatch_metrics_flow_from_shared_attention(fresh_registry):
    """shared_attention_batched feeds the dispatch-density metrics the
    serving engine exports."""
    from repro.core.router import Routing
    from repro.core.shared_attention import shared_attention_batched
    reg = fresh_registry
    obs.enable_jit_metrics(True)
    G, K, E, C, H, KH, D = 4, 2, 4, 8, 8, 2, 16
    key = jax.random.PRNGKey(0)
    k = jax.random.normal(jax.random.fold_in(key, 1), (E, C, KH, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (E, C, KH, D))
    q = jax.random.normal(jax.random.fold_in(key, 3), (G, 1, H, D))
    ids = jnp.tile(jnp.arange(K, dtype=jnp.int32)[None], (G, 1))
    r = Routing(ids, jnp.zeros((G, K)), jnp.zeros((G, E)))
    jax.block_until_ready(
        shared_attention_batched(q, k, v, r, capacity=G * K))
    util = reg.get("moska/dispatch_capacity_utilization")
    assert util is not None and util.count == 1
    assert reg.counter("moska/dispatched_queries").value == G * K
    assert reg.counter("moska/dropped_queries").value == 0


def test_jit_inc_per_labels_counters_by_traced_value(fresh_registry):
    """jit_inc_per forms the metric name host-side from a traced label —
    the per-layer counter mechanism (the label is a scan carry, not a
    static string)."""
    reg = fresh_registry
    obs.enable_jit_metrics(True)

    @jax.jit
    def f(x):
        def body(i, acc):
            obs.jit_inc_per("t/drops_by_layer", i, i * 10)
            return acc + i
        return jax.lax.fori_loop(0, 3, body, x)

    f(jnp.asarray(0)).block_until_ready()
    assert reg.get("t/drops_by_layer/L0").value == 0
    assert reg.get("t/drops_by_layer/L1").value == 10
    assert reg.get("t/drops_by_layer/L2").value == 20
    assert reg.get("t/drops_by_layer/L3") is None


def test_per_layer_dispatch_metrics_from_shared_attention(fresh_registry):
    """With layer_idx supplied, the dispatch path files utilization and
    dropped-query counts under per-layer names as well as the totals."""
    from repro.core.router import Routing
    from repro.core.shared_attention import shared_attention_batched
    reg = fresh_registry
    obs.enable_jit_metrics(True)
    G, K, E, C, H, KH, D = 4, 2, 4, 8, 8, 2, 16
    key = jax.random.PRNGKey(0)
    k = jax.random.normal(jax.random.fold_in(key, 1), (E, C, KH, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (E, C, KH, D))
    q = jax.random.normal(jax.random.fold_in(key, 3), (G, 1, H, D))
    ids = jnp.tile(jnp.arange(K, dtype=jnp.int32)[None], (G, 1))
    r = Routing(ids, jnp.zeros((G, K)), jnp.zeros((G, E)))
    jax.block_until_ready(shared_attention_batched(
        q, k, v, r, capacity=G * K, layer_idx=jnp.asarray(5)))
    util = reg.get("moska/dispatch_capacity_utilization_by_layer/L5")
    assert util is not None and util.count == 1
    assert reg.counter("moska/dropped_queries_by_layer/L5").value == 0
    # the totals still record alongside the per-layer views
    assert reg.counter("moska/dispatched_queries").value == G * K


def test_streaming_exporter_flush_cadence(fresh_registry, tmp_path):
    """StreamingExporter flushes every Nth tick, atomically, and the
    on-disk snapshot tracks the registry state at flush time."""
    reg = fresh_registry
    path = str(tmp_path / "live.json")
    exp = obs.StreamingExporter(path, every=2, reg=reg)
    with pytest.raises(ValueError):
        obs.StreamingExporter(path, every=0)

    reg.inc("waves")
    assert exp.tick() is False          # tick 1: no flush yet
    import os
    assert not os.path.exists(path)
    reg.inc("waves")
    assert exp.tick() is True           # tick 2: flush
    assert obs.load(path).counter("waves").value == 2
    assert not os.path.exists(path + ".tmp")    # atomic replace completed
    reg.inc("waves")
    exp.tick()
    assert obs.load(path).counter("waves").value == 2   # tick 3: stale
    exp.tick()
    assert obs.load(path).counter("waves").value == 3   # tick 4: fresh
    assert (exp.ticks, exp.flushes) == (4, 2)


# ---------------------------------------------------------------------------
# kernels/lse_merge.py edge cases
# ---------------------------------------------------------------------------

def _ref_merge(outs, lses):
    m = np.max(lses, axis=0)
    w = np.exp(lses - m[None])
    denom = np.sum(w, axis=0)
    out = np.sum(outs * w[..., None], axis=0) / np.maximum(
        denom, 1e-37)[..., None]
    return out, m + np.log(np.maximum(denom, 1e-37))


def test_lse_merge_matches_reference():
    key = jax.random.PRNGKey(1)
    P, N, H, D = 3, 8, 4, 16
    outs = jax.random.normal(jax.random.fold_in(key, 1), (P, N, H, D))
    lses = jax.random.normal(jax.random.fold_in(key, 2), (P, N, H))
    out, lse = lse_merge(outs, lses)
    ref_o, ref_l = _ref_merge(np.asarray(outs), np.asarray(lses))
    np.testing.assert_allclose(out, ref_o, rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(lse, ref_l, rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("sentinel", [NEG_INF, -np.inf])
def test_lse_merge_all_empty_rows(sentinel):
    """Rows where every partial is empty (-inf LSE): output must be
    finite (zero) and the merged LSE must stay at the sentinel floor."""
    P, N, H, D = 2, 4, 2, 8
    outs = jnp.zeros((P, N, H, D))
    lses = jnp.full((P, N, H), sentinel, jnp.float32)
    out, lse = lse_merge(outs, lses)
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_array_equal(np.asarray(out), 0.0)
    assert np.all(np.asarray(lse) <= NEG_INF / 2)
    assert np.isfinite(np.asarray(lse)).all()


def test_lse_merge_partial_empty_rows():
    """Mixing one empty partial with finite ones must equal merging the
    finite ones alone."""
    key = jax.random.PRNGKey(2)
    N, H, D = 6, 2, 8
    o1 = jax.random.normal(jax.random.fold_in(key, 1), (N, H, D))
    o2 = jax.random.normal(jax.random.fold_in(key, 2), (N, H, D))
    l1 = jax.random.normal(jax.random.fold_in(key, 3), (N, H))
    l2 = jax.random.normal(jax.random.fold_in(key, 4), (N, H))
    empty_o = jnp.zeros((N, H, D))
    empty_l = jnp.full((N, H), -jnp.inf)
    out3, lse3 = lse_merge(jnp.stack([o1, o2, empty_o]),
                           jnp.stack([l1, l2, empty_l]))
    out2, lse2 = lse_merge(jnp.stack([o1, o2]), jnp.stack([l1, l2]))
    np.testing.assert_allclose(out3, out2, rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(lse3, lse2, rtol=3e-5, atol=3e-5)


def test_lse_merge_associativity():
    """merge(merge(a, b), c) == merge(a, b, c) to fp32 tolerance."""
    key = jax.random.PRNGKey(3)
    N, H, D = 5, 2, 8
    parts = [(jax.random.normal(jax.random.fold_in(key, 10 + i),
                                (N, H, D)),
              5.0 * jax.random.normal(jax.random.fold_in(key, 20 + i),
                                      (N, H)))
             for i in range(3)]
    o_all, l_all = lse_merge(jnp.stack([p[0] for p in parts]),
                             jnp.stack([p[1] for p in parts]))
    o_ab, l_ab = lse_merge(jnp.stack([parts[0][0], parts[1][0]]),
                           jnp.stack([parts[0][1], parts[1][1]]))
    o_fin, l_fin = lse_merge(jnp.stack([o_ab, parts[2][0]]),
                             jnp.stack([l_ab, parts[2][1]]))
    np.testing.assert_allclose(o_fin, o_all, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(l_fin, l_all, rtol=1e-4, atol=1e-4)
