"""Scheduler property/invariant tests.

Invariants under arbitrary submit/decode/finish interleavings:
  * conservation — every submitted request is exactly one of queued,
    active, or finished; no slot is leaked or double-booked across refills
  * admission never exceeds the analytical memory budget
  * corpus-affinity steering never starves a queued corpus indefinitely
    (bounded by ``affinity_max_skips``)

Randomized hypothesis versions run when hypothesis is installed
(requirements-dev.txt); the deterministic fallback cases always run.
"""
import collections

import pytest

from repro import obs
from repro.core.scheduler import Scheduler, SchedulerConfig

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="hypothesis not installed "
    "(pip install -r requirements-dev.txt)")


def _drive(sched: Scheduler, rng, n_requests, corpora, max_steps=10_000):
    """Random submit/decode walk; checks invariants at every step.
    Returns total schedule() calls until drain."""
    submitted = 0
    steps = 0
    while (submitted < n_requests or not sched.idle) and steps < max_steps:
        steps += 1
        # random arrivals
        while submitted < n_requests and rng.random() < 0.5:
            cid = corpora[rng.integers(0, len(corpora))]
            sched.submit([1, 2, 3], int(rng.integers(1, 4)), cid)
            submitted += 1
        sched.schedule()
        _check_conservation(sched, submitted)
        _check_budget(sched)
        _check_single_corpus_wave(sched)
        # one decode wave: every active request yields a token
        for req in list(sched.active()):
            sched.record_token(req, 7)
        _check_conservation(sched, submitted)
    assert sched.idle, "scheduler failed to drain"
    assert len(sched.finished) == submitted
    return steps


def _check_conservation(sched: Scheduler, submitted: int):
    active = sched.active()
    # no slot double-booking; slot back-pointers consistent
    slots = [r.slot for r in active]
    assert len(set(slots)) == len(slots)
    for i, s in enumerate(sched.slots):
        if s is not None:
            assert s.slot == i and not s.done
    # partition: queued + active + finished == submitted
    assert len(sched.queue) + len(active) + len(sched.finished) == submitted
    # finished requests hold no slot (no leak across refills)
    assert all(r.slot == -1 for r in sched.finished)


def _check_budget(sched: Scheduler):
    assert sched._used_bytes() <= sched.cfg.mem_budget_bytes


def _check_single_corpus_wave(sched: Scheduler):
    """The decode step attends one shared store for all slots, so a wave
    must never mix corpora — and every active request must be on the
    corpus the engine will resolve the store from (resident_corpus)."""
    corpora = {r.corpus_id for r in sched.active()}
    assert len(corpora) <= 1, f"mixed-corpus wave: {corpora}"
    if corpora:
        assert corpora == {sched.resident_corpus}


# ---------------------------------------------------------------------------
# deterministic cases (always run)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed,max_slots,n_requests,n_corpora", [
    (0, 1, 5, 1), (1, 4, 20, 2), (2, 3, 17, 3), (3, 8, 40, 1),
])
def test_no_slot_leak_random_walk(seed, max_slots, n_requests, n_corpora):
    import numpy as np
    sched = Scheduler(SchedulerConfig(max_slots=max_slots))
    corpora = [f"c{i}" for i in range(n_corpora)]
    _drive(sched, np.random.default_rng(seed), n_requests, corpora)


@pytest.mark.parametrize("budget_slots", [1, 2, 3])
def test_admission_respects_memory_budget(budget_slots):
    """Budget for exactly N slots: never more than N admitted at once,
    and used bytes never exceed the analytical budget."""
    per_slot = 1000 * 64          # unique_bytes_per_token * max_seq
    cfg = SchedulerConfig(max_slots=8, unique_bytes_per_token=1000,
                          max_seq=64,
                          mem_budget_bytes=budget_slots * per_slot)
    sched = Scheduler(cfg)
    for _ in range(6):
        sched.submit([1], 2, "c0")
    while not sched.idle:
        sched.schedule()
        assert len(sched.active()) <= budget_slots
        _check_budget(sched)
        for req in list(sched.active()):
            sched.record_token(req, 7)
    assert len(sched.finished) == 6


def test_affinity_no_indefinite_starvation():
    """A lone request on corpus B must get a slot despite a sustained
    stream on resident corpus A — within the affinity_max_skips bound."""
    max_skips = 4
    sched = Scheduler(SchedulerConfig(max_slots=1, affinity_max_skips=max_skips))
    sched.submit([1], 1, "A")
    sched.schedule()                       # A becomes resident
    for req in list(sched.active()):
        sched.record_token(req, 7)
    starved_uid = sched.submit([1], 1, "B")
    waves = 0
    served_b = False
    # sustained stream of A-traffic: one new A request per wave
    while waves < max_skips + 10 and not served_b:
        sched.submit([1], 1, "A")
        sched.schedule()
        for req in list(sched.active()):
            served_b |= req.uid == starved_uid
            sched.record_token(req, 7)
        waves += 1
    assert served_b, f"corpus B starved for {waves} waves"
    assert waves <= max_skips + 2
    reg = obs.get_registry()
    assert reg.counter("scheduler/affinity_preemptions").value >= 1


def test_wave_never_mixes_corpora():
    """Regression (wrong-store decode): an affinity miss used to pop a
    request on another corpus into a live wave without flipping residency,
    so the engine fed every slot the resident store. Mismatched requests
    must be deferred until the resident wave drains."""
    sched = Scheduler(SchedulerConfig(max_slots=4))
    sched.submit([1], 3, "A")
    sched.submit([1], 1, "B")
    sched.submit([1], 3, "A")
    waves = 0
    while not sched.idle and waves < 50:
        sched.schedule()
        _check_single_corpus_wave(sched)
        for req in list(sched.active()):
            sched.record_token(req, 7)
        waves += 1
    assert sched.idle
    # B was deferred, not dropped: it ran in its own (post-drain) wave
    assert {r.corpus_id for r in sched.finished} == {"A", "B"}
    # and residency flipped to B when it ran
    b = next(r for r in sched.finished if r.corpus_id == "B")
    assert b.generated == [7]


def test_mixed_none_and_corpus_never_share_wave():
    """corpus_id=None (no store) counts as its own corpus: the decode
    step's use_store flag is wave-global, so a None request must not ride
    in a store-attached wave."""
    sched = Scheduler(SchedulerConfig(max_slots=2))
    sched.submit([1], 2, "A")
    sched.submit([1], 2, None)
    sched.submit([1], 2, "A")
    waves = 0
    while not sched.idle and waves < 50:
        sched.schedule()
        _check_single_corpus_wave(sched)
        for req in list(sched.active()):
            sched.record_token(req, 7)
        waves += 1
    assert sched.idle and len(sched.finished) == 3


def test_submit_validation():
    sched = Scheduler(SchedulerConfig(max_slots=1, max_seq=32))
    with pytest.raises(ValueError, match="max_new_tokens"):
        sched.submit([1, 2], 0)
    with pytest.raises(ValueError, match="max_new_tokens"):
        sched.submit([1, 2], -3)
    with pytest.raises(ValueError, match="empty prompt"):
        sched.submit([], 4)
    with pytest.raises(ValueError, match="max_seq"):
        sched.submit([1] * 30, 4)
    # nothing was enqueued by the rejected submissions
    assert not sched.queue
    sched.submit([1, 2], 1)
    assert len(sched.queue) == 1


def test_affinity_still_prefers_resident_corpus():
    """Sanity: under the skip bound, affinity still batches the resident
    corpus ahead of FIFO order."""
    sched = Scheduler(SchedulerConfig(max_slots=2, affinity_max_skips=100))
    sched.submit([1], 1, "A")
    sched.submit([1], 1, "B")
    sched.submit([1], 1, "A")
    admitted = sched.schedule()
    assert [r.corpus_id for r in admitted] == ["A", "A"]


def test_lookahead_previews_admission_order_without_mutating():
    """lookahead(n) mirrors affinity order (resident corpus first, then
    the flip corpus) and never admits, counts skips, or edits the queue
    — the prefetch engine's hint must be side-effect free."""
    sched = Scheduler(SchedulerConfig(max_slots=1))
    sched.submit([1], 1, "A")
    sched.submit([2], 1, "B")
    sched.submit([3], 1, "A")
    sched.submit([4], 1, "B")
    admitted = sched.schedule()          # residency -> A, [1] admitted
    assert [r.corpus_id for r in admitted] == ["A"]

    before = [(r.uid, r.skips) for r in sched.queue]
    # resident-corpus traffic first (queue order), then the flip corpus
    assert [r.prompt for r in sched.lookahead(3)] == [[3], [2], [4]]
    assert [r.prompt for r in sched.lookahead(1)] == [[3]]
    assert sched.lookahead(0) == []
    assert [(r.uid, r.skips) for r in sched.queue] == before
    assert sched.resident_corpus == "A"

    # drain: the remaining admission sequence matches the preview
    seq = []
    while not sched.idle:
        for r in sched.schedule():
            seq.append(r.prompt)
        for r in list(sched.active()):
            sched.record_token(r, 7)
    assert seq == [[3], [2], [4]]


# ---------------------------------------------------------------------------
# hypothesis property versions
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    @needs_hypothesis
    @given(st.integers(0, 2**31 - 1), st.integers(1, 8),
           st.integers(0, 30), st.integers(1, 3))
    @settings(max_examples=25, deadline=None)
    def test_property_no_slot_leak(seed, max_slots, n_requests, n_corpora):
        import numpy as np
        sched = Scheduler(SchedulerConfig(max_slots=max_slots))
        corpora = [f"c{i}" for i in range(n_corpora)]
        _drive(sched, np.random.default_rng(seed), n_requests, corpora)

    @needs_hypothesis
    @given(st.integers(0, 2**31 - 1), st.integers(1, 4))
    @settings(max_examples=25, deadline=None)
    def test_property_budget(seed, budget_slots):
        import numpy as np
        per_slot = 100 * 16
        cfg = SchedulerConfig(max_slots=8, unique_bytes_per_token=100,
                              max_seq=16,
                              mem_budget_bytes=budget_slots * per_slot)
        sched = Scheduler(cfg)
        _drive(sched, np.random.default_rng(seed), 12, ["c0", "c1"])
