"""Serving hot-path tests: donated persistent cache, bucketed prefill,
per-slot cache writes, mixed-corpus wave isolation, livelock detection.

Differential guarantees:
  * donation + persistent cache produce bit-identical generations to the
    copying (donate_cache=False) path — donation only aliases buffers
  * bucketed prefill (pad + masked routing + dynamic logit index) produces
    the same generations as exact-length prefill
  * a prompt-length sweep compiles at most one prefill program per bucket
  * per-slot writes never leak stale KV across slot reuse (dtypes, offsets)
  * corpus-B requests in a mixed-corpus stream decode against store B
    (regression: the scheduler used to mix corpora into one wave and the
    engine fed every slot the resident store)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.configs import get_config
from repro.data.pipeline import CorpusSpec, synthesize_corpus
from repro.kvcache.cache import (KVCache, init_kv_cache, read_slot,
                                 write_slot_prefix)
from repro.models.model import build_model
from repro.serving.engine import (EngineConfig, ServingEngine, bucket_for,
                                  resolve_prefill_buckets)

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("tinyllama-1.1b").reduced()
    model = build_model(cfg)
    params = model.init(KEY)
    return cfg, params


def _fresh_registry():
    reg = obs.MetricsRegistry()
    return reg, obs.set_registry(reg)


def _run(cfg, params, ecfg, requests, corpora=()):
    """Run one engine on a fresh registry; returns (finished, registry)."""
    reg, prev = _fresh_registry()
    try:
        eng = ServingEngine(cfg, params, ecfg)
        for cid, toks in corpora:
            eng.register_corpus(cid, toks)
        for prompt, new, cid in requests:
            eng.submit(prompt, max_new_tokens=new, corpus_id=cid)
        done = eng.run()
    finally:
        obs.set_registry(prev)
    return done, reg


def _gen(done):
    return {r.uid: tuple(r.generated) for r in done}


# ---------------------------------------------------------------------------
# bucket resolution
# ---------------------------------------------------------------------------

def test_auto_buckets():
    assert resolve_prefill_buckets("auto", 64) == (16, 32, 64)
    assert resolve_prefill_buckets("auto", 128) == (16, 32, 64, 128)
    assert resolve_prefill_buckets("auto", 512) == (16, 32, 64, 128, 256,
                                                    384, 512)
    assert resolve_prefill_buckets(None, 64) is None
    assert resolve_prefill_buckets((), 64) is None
    assert resolve_prefill_buckets([64, 16], 64) == (16, 64)
    with pytest.raises(ValueError):
        resolve_prefill_buckets([144], 512)   # >128, not a 128-multiple
    with pytest.raises(ValueError):
        resolve_prefill_buckets([96], 64)     # above max_seq


def test_bucket_for_rounds_up_and_falls_back():
    b = (16, 32, 64)
    assert bucket_for(b, 1) == 16
    assert bucket_for(b, 16) == 16
    assert bucket_for(b, 17) == 32
    assert bucket_for(b, 65) == 65            # overflow: exact length
    assert bucket_for(None, 23) == 23


# ---------------------------------------------------------------------------
# per-slot cache writes (the zero-copy admission path)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_write_slot_prefix_no_stale_leak(dtype):
    """Reusing a slot must not leak the previous request's KV beyond the
    new prompt length — neither pad garbage inside the bucket nor stale
    tokens beyond it."""
    L, B, S, KH, D = 2, 3, 16, 2, 4
    cache = init_kv_cache(L, B, S, KH, D, dtype)
    # simulate a previous long request occupying slot 1
    stale = KVCache(jnp.full_like(cache.k, 7.0), jnp.full_like(cache.v, 9.0),
                    jnp.full((B,), S, jnp.int32), jnp.zeros((B,), jnp.int32))
    # new request: true length 3 padded into an 8-token bucket, store offset
    Sb, true_len, offset = 8, 3, 128
    k_new = jax.random.normal(KEY, (L, 1, Sb, KH, D), dtype)
    v_new = jax.random.normal(jax.random.fold_in(KEY, 1), (L, 1, Sb, KH, D),
                              dtype)
    slot_cache = KVCache(k_new, v_new, jnp.full((1,), true_len, jnp.int32),
                         jnp.full((1,), offset, jnp.int32))
    out = write_slot_prefix(stale, slot_cache, 1, true_len)
    # prefix [0, true_len) is the new KV
    np.testing.assert_array_equal(np.asarray(out.k[:, 1, :true_len]),
                                  np.asarray(k_new[:, 0, :true_len]))
    # everything beyond true_len is zero — no pad garbage, no stale KV
    assert not np.any(np.asarray(out.k[:, 1, true_len:]))
    assert not np.any(np.asarray(out.v[:, 1, true_len:]))
    assert int(out.length[1]) == true_len
    assert int(out.offset[1]) == offset
    # other slots untouched
    for s in (0, 2):
        np.testing.assert_array_equal(np.asarray(out.k[:, s]),
                                      np.asarray(stale.k[:, s]))
        assert int(out.length[s]) == S


def test_write_slot_prefix_matches_merge_reference():
    """For an exact-length (unpadded) prefix the in-place write equals the
    old full-copy merge on the written region."""
    from repro.serving.engine import _merge_slot_cache
    L, B, S, KH, D = 2, 4, 12, 2, 4
    cache = init_kv_cache(L, B, S, KH, D, jnp.float32)
    Sb = 5
    slot_cache = KVCache(
        jax.random.normal(KEY, (L, 1, Sb, KH, D)),
        jax.random.normal(jax.random.fold_in(KEY, 2), (L, 1, Sb, KH, D)),
        jnp.full((1,), Sb, jnp.int32), jnp.full((1,), 64, jnp.int32))
    a = write_slot_prefix(cache, slot_cache, 2, Sb)
    b = _merge_slot_cache(cache, slot_cache, 2)
    np.testing.assert_array_equal(np.asarray(a.k), np.asarray(b.k))
    np.testing.assert_array_equal(np.asarray(a.v), np.asarray(b.v))
    np.testing.assert_array_equal(np.asarray(a.length), np.asarray(b.length))
    np.testing.assert_array_equal(np.asarray(a.offset), np.asarray(b.offset))
    got = read_slot(a, 2)
    np.testing.assert_array_equal(np.asarray(got.k[:, 0, :Sb]),
                                  np.asarray(slot_cache.k[:, 0]))


def test_write_slot_prefix_donatable():
    """The write must be expressible as an in-place update: jit with
    donation consumes the batch cache and the result is correct."""
    L, B, S, KH, D = 1, 2, 8, 1, 4
    cache = init_kv_cache(L, B, S, KH, D, jnp.float32)
    slot_cache = KVCache(
        jnp.ones((L, 1, 4, KH, D)), 2 * jnp.ones((L, 1, 4, KH, D)),
        jnp.full((1,), 4, jnp.int32), jnp.zeros((1,), jnp.int32))
    wr = jax.jit(write_slot_prefix, donate_argnums=(0,))
    out = wr(cache, slot_cache, jnp.int32(1), jnp.int32(4))
    assert np.asarray(out.k[:, 1, :4]).all()
    with pytest.raises(RuntimeError):
        _ = np.asarray(cache.k)   # donated: input buffer was consumed


# ---------------------------------------------------------------------------
# differential: donation + persistence + bucketing change nothing observable
# ---------------------------------------------------------------------------

REQS = [([3 + i] * (5 + 3 * i), 4, "laws") for i in range(5)]


def test_donated_persistent_equals_copying_path(tiny):
    cfg, params = tiny
    corpus = synthesize_corpus(CorpusSpec("laws", 256, cfg.vocab_size))
    donated, reg_d = _run(cfg, params,
                          EngineConfig(max_slots=3, max_seq=64),
                          REQS, [("laws", corpus)])
    copying, reg_c = _run(cfg, params,
                          EngineConfig(max_slots=3, max_seq=64,
                                       donate_cache=False),
                          REQS, [("laws", corpus)])
    assert _gen(donated) == _gen(copying)
    assert reg_d.gauge("engine/decode_cache_bytes_copied").value == 0
    assert reg_c.gauge("engine/decode_cache_bytes_copied").value > 0


def test_bucketed_prefill_equals_exact_prefill(tiny):
    """Pad + masked routing + dynamic logit index == exact-length prefill:
    the compile-count win must not change a single generated token."""
    cfg, params = tiny
    corpus = synthesize_corpus(CorpusSpec("laws", 256, cfg.vocab_size))
    bucketed, reg_b = _run(cfg, params,
                           EngineConfig(max_slots=3, max_seq=64),
                           REQS, [("laws", corpus)])
    exact, _ = _run(cfg, params,
                    EngineConfig(max_slots=3, max_seq=64,
                                 prefill_buckets=None),
                    REQS, [("laws", corpus)])
    assert _gen(bucketed) == _gen(exact)
    # 5 distinct prompt lengths (5, 8, 11, 14, 17) but <= 2 programs
    # (buckets 16 and 32)
    assert reg_b.gauge("engine/prefill_compile_count").value <= 2


def test_prefill_compile_count_bounded_by_buckets(tiny):
    """Prompt-length sweep: the prefill jit cache stops growing per prompt
    — at most one program per bucket."""
    cfg, params = tiny
    corpus = synthesize_corpus(CorpusSpec("laws", 256, cfg.vocab_size))
    lengths = [17, 18, 33, 34, 65, 66, 129, 130]
    reqs = [([2] * n, 2, "laws") for n in lengths]
    done, reg = _run(cfg, params,
                     EngineConfig(max_slots=2, max_seq=256), reqs,
                     [("laws", corpus)])
    assert len(done) == len(lengths)
    buckets = resolve_prefill_buckets("auto", 256)
    compiles = reg.gauge("engine/prefill_compile_count").value
    assert compiles <= len(buckets), (compiles, buckets)
    assert compiles == 4   # 17/18->32, 33/34->64, 65/66->128, 129/130->256


def test_run_callable_repeatedly_with_slot_reuse(tiny):
    """The persistent cache survives run() boundaries, and a reused slot
    (previously holding a longer request) decodes the same tokens as a
    fresh engine — no stale-KV bleed-through."""
    cfg, params = tiny
    corpus = synthesize_corpus(CorpusSpec("laws", 256, cfg.vocab_size))
    reg, prev = _fresh_registry()
    try:
        eng = ServingEngine(cfg, params, EngineConfig(max_slots=2,
                                                      max_seq=64))
        eng.register_corpus("laws", corpus)
        eng.submit([9] * 40, max_new_tokens=4, corpus_id="laws")  # long
        first = eng.run()
        assert len(first) == 1
        # second run reuses slot 0 with a much shorter prompt
        eng.submit([4, 5, 6], max_new_tokens=5, corpus_id="laws")
        second = [r for r in eng.run() if r.uid != first[0].uid]
    finally:
        obs.set_registry(prev)
    fresh, _ = _run(cfg, params, EngineConfig(max_slots=2, max_seq=64),
                    [([4, 5, 6], 5, "laws")], [("laws", corpus)])
    assert tuple(second[0].generated) == tuple(fresh[0].generated)


# ---------------------------------------------------------------------------
# mixed-corpus regression: corpus-B requests attend store B
# ---------------------------------------------------------------------------

def test_mixed_corpus_requests_decode_against_their_store(tiny):
    """Regression for the wrong-store decode: with corpora A and B
    interleaved in one stream, every B request must generate exactly what
    it generates on an engine that only ever saw store B."""
    cfg, params = tiny
    corpus_a = synthesize_corpus(CorpusSpec("A", 256, cfg.vocab_size,
                                            seed=1))
    corpus_b = synthesize_corpus(CorpusSpec("B", 256, cfg.vocab_size,
                                            seed=2))
    ecfg = EngineConfig(max_slots=3, max_seq=64)
    b_prompts = [[7, 8, 9, 10], [11, 12, 13]]
    mixed_reqs = [([1] * 6, 4, "A"), (b_prompts[0], 4, "B"),
                  ([2] * 6, 4, "A"), (b_prompts[1], 4, "B"),
                  ([3] * 6, 4, "A")]
    mixed, _ = _run(cfg, params, ecfg, mixed_reqs,
                    [("A", corpus_a), ("B", corpus_b)])
    only_b, _ = _run(cfg, params, ecfg,
                     [(p, 4, "B") for p in b_prompts], [("B", corpus_b)])
    got_b = sorted(tuple(r.generated) for r in mixed
                   if r.corpus_id == "B")
    want_b = sorted(tuple(r.generated) for r in only_b)
    assert got_b == want_b
    # and the A requests all finished too
    assert sum(r.corpus_id == "A" for r in mixed) == 3


# ---------------------------------------------------------------------------
# livelock + submit-time validation through the engine
# ---------------------------------------------------------------------------

def test_run_raises_instead_of_livelock(tiny):
    cfg, params = tiny
    reg, prev = _fresh_registry()
    try:
        # budget below one slot's cost: nothing is ever admissible
        eng = ServingEngine(cfg, params,
                            EngineConfig(max_slots=2, max_seq=64,
                                         mem_budget_bytes=1.0))
        eng.submit([1, 2, 3], max_new_tokens=2)
        with pytest.raises(RuntimeError, match="livelock"):
            eng.run()
        assert reg.counter("scheduler/admission_deferred_mem").value >= 1
    finally:
        obs.set_registry(prev)


def test_zero_new_tokens_rejected_and_one_token_finishes(tiny):
    cfg, params = tiny
    eng = ServingEngine(cfg, params, EngineConfig(max_slots=1, max_seq=32))
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.submit([1, 2, 3], max_new_tokens=0)
    # max_new_tokens=1: the prefill's token finishes the request; no decode
    # wave runs and remaining never goes negative
    eng.submit([1, 2, 3], max_new_tokens=1)
    done = eng.run()
    assert len(done) == 1
    assert len(done[0].generated) == 1
    assert done[0].remaining == 0
    assert eng.metrics["decode_steps"] == 0
