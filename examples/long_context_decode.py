"""Long-context decode via MoSKA routing (the long_500k mechanism at
reduced scale): a context far larger than what full attention would read
per step is registered as shared chunks; each decode step reads only the
routed top-k — sub-quadratic in context length — and the output provably
matches full attention when routing is exhaustive.

Also demonstrates the Pallas kernel path (interpret mode on CPU).

    PYTHONPATH=src python examples/long_context_decode.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import build_store
from repro.kvcache import init_kv_cache
from repro.models import dense

cfg = dataclasses.replace(get_config("llama3-8b").reduced(),
                          dtype="float32")
key = jax.random.PRNGKey(0)
params = dense.init_params(cfg, key)

# a "long" context: 16 chunks; decode reads top-2 => 8x fewer tokens/step
ctx_len = 16 * cfg.moska.chunk_size
ctx = jax.random.randint(jax.random.fold_in(key, 1), (1, ctx_len), 0,
                         cfg.vocab_size)
ccache = init_kv_cache(cfg.num_layers, 1, ctx_len, cfg.num_kv_heads,
                       cfg.head_dim, jnp.float32)
_, ccache = dense.prefill(cfg, params, ctx, ccache)
store = build_store(ccache.k[:, 0], ccache.v[:, 0], cfg.moska.chunk_size)
print(f"context: {ctx_len} tokens as {store.num_chunks} chunks; "
      f"router reads top-{cfg.moska.top_k_chunks} per step "
      f"({100 * cfg.moska.top_k_chunks / store.num_chunks:.0f}% of context)")

B = 2
prompt = jax.random.randint(jax.random.fold_in(key, 2), (B, 8), 0,
                            cfg.vocab_size)
cache = init_kv_cache(cfg.num_layers, B, 64, cfg.num_kv_heads,
                      cfg.head_dim, jnp.float32)
logits, cache = dense.prefill(cfg, params, prompt, cache, store=store,
                              start_pos=ctx_len)
tok = jnp.argmax(logits, -1).astype(jnp.int32)

decode = jax.jit(lambda t, c: dense.decode_step(cfg, params, t, c,
                                                store=store))
toks = []
t0 = time.perf_counter()
for _ in range(8):
    logits, cache = decode(tok, cache)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    toks.append(np.asarray(tok))
print(f"decoded 8 tokens x {B} requests in "
      f"{time.perf_counter() - t0:.1f}s: {np.stack(toks)[:, 0]}")

# kernel-path parity (Pallas interpret mode)
l_jnp, _ = dense.decode_step(cfg, params, tok, cache, store=store)
l_pal, _ = dense.decode_step(cfg, params, tok, cache, store=store,
                             kernel="pallas")
print(f"pallas-vs-jnp decode max|diff| = "
      f"{float(jnp.max(jnp.abs(l_jnp - l_pal))):.2e}")
assert float(jnp.max(jnp.abs(l_jnp - l_pal))) < 1e-3
print("OK")
