"""Train a ~100M-param model for a few hundred steps (deliverable (b)).

Uses the full (non-reduced) mamba2-130m config by default — small enough
for CPU — or any --arch at --reduced scale. Shows loss descending on the
synthetic copy-structured LM task and writes checkpoints.

    PYTHONPATH=src python examples/train_tiny.py [--steps 200]
"""
import argparse
import dataclasses
import tempfile

from repro.configs import get_config
from repro.training.train_loop import TrainLoopConfig, train

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="mamba2-130m")
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--seq", type=int, default=256)
ap.add_argument("--reduced", action="store_true")
args = ap.parse_args()

cfg = get_config(args.arch)
if args.reduced:
    cfg = cfg.reduced()
print(f"training {cfg.name}: {cfg.param_count()/1e6:.0f}M params, "
      f"{args.steps} steps @ batch {args.batch} x seq {args.seq}")

with tempfile.TemporaryDirectory() as ckpt_dir:
    out = train(cfg, TrainLoopConfig(
        num_steps=args.steps, batch_size=args.batch, seq_len=args.seq,
        lr=3e-4, warmup=20, log_every=20,
        ckpt_dir=ckpt_dir, ckpt_every=max(args.steps // 2, 1)))
hist = out["history"]
print(f"loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")
assert hist[-1]["loss"] < hist[0]["loss"], "training failed to descend"
print("OK")
