"""Quickstart: the MoSKA mechanism in ~60 lines.

Builds a small dense model, precomputes a shared corpus' KV chunks,
and shows that routed Shared-KV-Attention decode (a) matches monolithic
attention under full routing, and (b) reads only top-k chunks when sparse.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import build_store
from repro.kvcache import init_kv_cache
from repro.models import dense

cfg = dataclasses.replace(get_config("tinyllama-1.1b").reduced(),
                          dtype="float32")
key = jax.random.PRNGKey(0)
params = dense.init_params(cfg, key)
print(f"model: {cfg.name} ({cfg.num_layers}L d={cfg.d_model})")

# --- 1. precompute the shared corpus KV once (the persistent asset) ------
corpus_len = 256
corpus = jax.random.randint(jax.random.fold_in(key, 1), (1, corpus_len),
                            0, cfg.vocab_size)
ccache = init_kv_cache(cfg.num_layers, 1, corpus_len, cfg.num_kv_heads,
                       cfg.head_dim, jnp.float32)
_, ccache = dense.prefill(cfg, params, corpus, ccache)
store = build_store(ccache.k[:, 0], ccache.v[:, 0], cfg.moska.chunk_size)
print(f"shared store: {store.num_chunks} chunks x {store.chunk_size} tokens")

# --- 2. concurrent requests decode against the shared store --------------
B, S = 4, 12
prompts = jax.random.randint(jax.random.fold_in(key, 2), (B, S), 0,
                             cfg.vocab_size)
cache = init_kv_cache(cfg.num_layers, B, S + 8, cfg.num_kv_heads,
                      cfg.head_dim, jnp.float32)
logits, cache = dense.prefill(cfg, params, prompts, cache, store=store,
                              start_pos=corpus_len)
nxt = jnp.argmax(logits, -1).astype(jnp.int32)
logits, cache = dense.decode_step(cfg, params, nxt, cache, store=store)
print("sparse routed decode logits[0,:4] =", np.asarray(logits)[0, :4])

# --- 3. exactness: full routing == monolithic context ---------------------
full = dataclasses.replace(cfg, moska=dataclasses.replace(
    cfg.moska, top_k_chunks=store.num_chunks))
cache2 = init_kv_cache(cfg.num_layers, B, S + 8, cfg.num_kv_heads,
                       cfg.head_dim, jnp.float32)
lg, cache2 = dense.prefill(full, params, prompts, cache2, store=store,
                           start_pos=corpus_len)
nxt2 = jnp.argmax(lg, -1).astype(jnp.int32)
lg, _ = dense.decode_step(full, params, nxt2, cache2, store=store)

mono = jnp.concatenate([jnp.tile(corpus, (B, 1)), prompts,
                        nxt2[:, None]], axis=1)
cache3 = init_kv_cache(cfg.num_layers, B, mono.shape[1] + 4,
                       cfg.num_kv_heads, cfg.head_dim, jnp.float32)
lm, _ = dense.prefill(cfg, params, mono, cache3)
err = float(jnp.max(jnp.abs(lg - lm)))
print(f"full-routing decode vs monolithic-context decode: max|diff|={err:.2e}")
assert err < 1e-3
print("OK — Shared KV Attention is exact under full routing.")
