"""End-to-end serving driver (deliverable (b)): a MoSKA engine serving
batched requests over two registered domain corpora with continuous
batching + corpus-affinity scheduling. This is the paper's deployment
story at reduced scale: corpora's KV precomputed once, concurrent
requests' queries routed and GEMM-batched against the shared chunks.

    PYTHONPATH=src python examples/serve_shared_corpus.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.scheduler import wave_stats
from repro.data.pipeline import CorpusSpec, synthesize_corpus
from repro.models.model import build_model
from repro.serving.engine import EngineConfig, ServingEngine

cfg = get_config("qwen1.5-0.5b").reduced()
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
eng = ServingEngine(cfg, params, EngineConfig(max_slots=4, max_seq=96))

for cid, seed in (("laws", 1), ("medical", 2)):
    corpus = synthesize_corpus(CorpusSpec(cid, 512, cfg.vocab_size, seed))
    t0 = time.perf_counter()
    n = eng.register_corpus(cid, corpus)
    print(f"registered corpus {cid!r}: {n} chunks "
          f"({time.perf_counter() - t0:.1f}s, one-time)")

rng = np.random.default_rng(0)
for i in range(10):
    cid = "laws" if i % 3 else "medical"
    eng.submit(rng.integers(0, cfg.vocab_size, 10).tolist(),
               max_new_tokens=8, corpus_id=cid)

t0 = time.perf_counter()
done = eng.run()
wall = time.perf_counter() - t0
print(f"finished {len(done)} requests in {wall:.1f}s — "
      f"{eng.metrics['tokens_generated']} tokens, "
      f"{eng.metrics['decode_steps']} decode waves "
      f"(batched {eng.metrics['tokens_generated'] / eng.metrics['decode_steps']:.1f} tok/wave)")
print("wave stats:", wave_stats(done))
reg = eng.registry
print(f"zero-copy hot path: decode cache bytes copied/wave = "
      f"{int(reg.gauge('engine/decode_cache_bytes_copied').value)} "
      f"(cache {int(reg.gauge('engine/decode_cache_bytes').value)}B), "
      f"{int(reg.gauge('engine/prefill_compile_count').value)} prefill "
      f"program(s) for {eng.metrics['prefills']} prefills "
      f"(buckets {list(eng.prefill_buckets or ())})")
for r in done[:3]:
    print(f"  req {r.uid} [{r.corpus_id}]: {r.generated}")
